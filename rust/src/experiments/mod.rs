//! Experiment drivers — one per paper table/figure (see DESIGN.md §5).
//!
//! Every driver regenerates its artifact (CSV + SVG + markdown) under
//! `reports/<id>/` from runs executed by the L3 coordinator. Completed runs
//! are cached as JSONL under `runs/<id>/` and reloaded on re-invocation
//! (`--force` reruns).
//!
//! Drivers are generic over the execution [`Engine`]: both the
//! proxy-model experiments and the LM-ladder experiments (fig1, fig16,
//! scaling) run on the native backend out of the box — the native engine
//! ships a built-in `lm_*` ladder. On engines without `lm_*` models
//! (PJRT without compiled bundles) the LM drivers degrade with a clear
//! message.

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig9;
pub mod fig10;
pub mod fig11;
pub mod fig16;
pub mod scaling;

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::Config;
use crate::coordinator::{Job, RunConfig, RunLog, Sweeper};
use crate::report::Report;
use crate::runtime::Engine;

pub struct Ctx<E: Engine> {
    pub cfg: Config,
    pub sweeper: Sweeper<E>,
    pub force: bool,
}

impl<E: Engine> Ctx<E> {
    pub fn new(cfg: Config, engine: Arc<E>, force: bool) -> Ctx<E> {
        let sweeper = Sweeper::new(engine);
        Ctx { cfg, sweeper, force }
    }

    pub fn report(&self, id: &str) -> Result<Report> {
        Report::new(&self.cfg.reports, id)
    }

    fn cache_dir(&self, exp: &str) -> PathBuf {
        self.cfg.runs.join(exp)
    }

    /// Run jobs with a JSONL cache per run name.
    pub fn sweep(&self, exp: &str, jobs: Vec<Job>) -> Result<Vec<RunLog>> {
        let dir = self.cache_dir(exp);
        std::fs::create_dir_all(&dir)?;
        let mut cached: Vec<Option<RunLog>> = Vec::with_capacity(jobs.len());
        let mut todo: Vec<Job> = vec![];
        for j in &jobs {
            let hit = if self.force {
                None
            } else {
                RunLog::load(&dir, &j.cfg.name).ok().filter(|l| !l.rows.is_empty())
            };
            if hit.is_none() {
                todo.push(j.clone());
            }
            cached.push(hit);
        }
        if !todo.is_empty() {
            eprintln!(
                "[{}] running {} jobs ({} cached)",
                exp,
                todo.len(),
                jobs.len() - todo.len()
            );
            let fresh = self.sweeper.run_all(&todo, self.cfg.quiet);
            for log in fresh {
                log.save(&dir)?;
                let slot = cached
                    .iter_mut()
                    .zip(&jobs)
                    .find(|(c, j)| c.is_none() && j.cfg.name == log.name);
                if let Some((slot, _)) = slot {
                    *slot = Some(log);
                }
            }
        }
        Ok(cached.into_iter().map(|c| c.unwrap()).collect())
    }

    /// Single cached run (outside the scheduler — used by drivers that need
    /// the final state, e.g. fig7 snapshots).
    pub fn single(&self, exp: &str, bundle: &str, cfg: &RunConfig) -> Result<RunLog> {
        let mut logs = self.sweep(
            exp,
            vec![Job { bundle: bundle.to_string(), cfg: cfg.clone() }],
        )?;
        Ok(logs.remove(0))
    }
}

/// All known experiment ids in run order.
pub const ALL: &[&str] = &[
    // Core-claim experiments first so partial sweeps still cover the
    // paper's headline results.
    "fig4", "fig5", "fig7", "scaling", "fig1", "fig2", "fig6", "fig9",
    "fig3", "fig10", "fig11", "fig16",
];

pub fn run<E: Engine>(ctx: &Ctx<E>, id: &str) -> Result<()> {
    match id {
        "fig1" => fig1::run(ctx),
        "fig2" => fig2::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => fig4::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig6" => fig6::run(ctx),
        "fig7" => fig7::run(ctx),
        "fig9" => fig9::run(ctx),
        "fig10" => fig10::run(ctx),
        "fig11" => fig11::run(ctx),
        "fig16" | "fig17" => fig16::run(ctx),
        "scaling" | "fig8" | "fig12" | "fig13" | "tab1" | "tab2" | "tab45" => scaling::run(ctx),
        "all" => {
            // LM-ladder experiments bail with "no lm_* models" on engines
            // without them (e.g. the native backend); that inapplicability
            // must not abort the proxy experiments. Anything else is a
            // genuine failure and propagates.
            let mut skipped = vec![];
            for e in ALL {
                eprintln!("=== experiment {e} ===");
                match run(ctx, e) {
                    Ok(()) => {}
                    Err(err) if format!("{err:#}").contains("no lm_* models") => {
                        eprintln!("[{e}] not applicable on this engine: {err:#}");
                        skipped.push(*e);
                    }
                    Err(err) => return Err(err),
                }
            }
            if skipped.len() == ALL.len() {
                bail!("every experiment was inapplicable: {skipped:?}");
            }
            if !skipped.is_empty() {
                eprintln!("(skipped as not applicable: {skipped:?})");
            }
            Ok(())
        }
        _ => bail!("unknown experiment {id:?}; known: {ALL:?} or 'all'"),
    }
}
