//! Experiment configuration: paths, scale presets and CLI overrides.

use std::path::PathBuf;

use anyhow::Result;

use crate::util::args::Args;

/// Global scale preset — controls step counts and ladder sizes so the
/// paper-figure experiments can be smoke-tested (`quick`), run at the
/// calibrated default, or extended (`full`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Quick,
    Default,
    Full,
}

impl Scale {
    pub fn parse(s: &str) -> Scale {
        match s {
            "quick" => Scale::Quick,
            "full" => Scale::Full,
            _ => Scale::Default,
        }
    }

    /// Multiply a default step count by the preset's factor.
    pub fn steps(&self, default: usize) -> usize {
        match self {
            Scale::Quick => (default / 10).max(20),
            Scale::Default => default,
            Scale::Full => default * 2,
        }
    }
}

/// Resolved experiment context shared by all drivers.
#[derive(Debug, Clone)]
pub struct Config {
    pub artifacts: PathBuf,
    pub reports: PathBuf,
    pub runs: PathBuf,
    pub scale: Scale,
    /// Optional overrides.
    pub steps_override: Option<usize>,
    pub seeds: usize,
    pub quiet: bool,
}

impl Config {
    pub fn from_args(args: &Args) -> Result<Config> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
        let cfg = Config {
            artifacts: args
                .get("artifacts")
                .map(PathBuf::from)
                .unwrap_or_else(|| root.join("artifacts")),
            reports: args
                .get("reports")
                .map(PathBuf::from)
                .unwrap_or_else(|| root.join("reports")),
            runs: args
                .get("runs")
                .map(PathBuf::from)
                .unwrap_or_else(|| root.join("runs")),
            scale: Scale::parse(args.get_or("scale", "default")),
            steps_override: args.get("steps").and_then(|s| s.parse().ok()),
            seeds: args.parse_or("seeds", 1usize)?,
            quiet: args.flag("quiet"),
        };
        Ok(cfg)
    }

    pub fn steps(&self, default: usize) -> usize {
        self.steps_override.unwrap_or_else(|| self.scale.steps(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_presets() {
        assert_eq!(Scale::Quick.steps(1000), 100);
        assert_eq!(Scale::Default.steps(1000), 1000);
        assert_eq!(Scale::Full.steps(1000), 2000);
        assert_eq!(Scale::Quick.steps(50), 20, "floor at 20");
    }

    #[test]
    fn overrides_win() {
        let args = crate::util::args::Args::parse(
            ["x", "--steps", "42", "--scale", "quick"].iter().map(|s| s.to_string()),
        );
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.steps(1000), 42);
        assert_eq!(cfg.scale, Scale::Quick);
    }
}
