//! # mxstab
//!
//! Reproduction of *"Characterization and Mitigation of Training
//! Instabilities in Microscaling Formats"* (Su et al., 2025) as a
//! three-layer Rust + JAX + Pallas training-systems stack.
//!
//! Layer map (see `DESIGN.md`):
//! * **L3 (this crate)** — the training coordinator: sweep scheduling,
//!   run state machines, instability detection, in-situ interventions,
//!   metrics, scaling-law fits, and every report/table/figure generator.
//! * **L2** — JAX model step functions (residual-MLP proxy + OLMo-style LM),
//!   AOT-lowered to HLO text under `artifacts/` by `python/compile/aot.py`.
//! * **L1** — the Pallas MX quantize→dequantize kernel feeding L2's GEMMs.
//!
//! Python never runs on the training path. Execution is pluggable behind
//! `runtime::Backend` / `runtime::Engine`: the **native backend**
//! (default) trains the paper's residual-MLP proxy entirely in rust on
//! the packed MX engine, while `--features xla` adds the PJRT backend
//! that loads compiled HLO artifacts through the PJRT C API.
//!
//! Build surface: the default feature set is **PJRT-free** — the formats
//! substrate (scalar oracle + packed codec/GEMM engine), the native
//! backend, the full coordinator (Runner/Sweeper/CheckpointStore,
//! detector, interventions), the experiment drivers, analysis and report
//! all build, test and *run* on a bare machine. Only actual PJRT
//! execution sits behind `xla` (DESIGN.md §6).

pub mod analysis;
pub mod analyze;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod formats;
pub mod report;
pub mod runtime;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
