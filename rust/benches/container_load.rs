//! Container bench: `.mxc` model load vs the f32 re-encode baseline.
//!
//! Measures what PR 9's zero-copy container buys at startup. Three timed
//! rows per run:
//!
//! - `open`: [`MxcFile::open`] alone — header parse + structural
//!   validation + mmap. O(header): must not scale with model size.
//! - `open+load_weights`: the full `--weights model.mxc` startup — open,
//!   restore the checksummed master tensors, and seed every pre-packed
//!   forward weight operand into the exec cache as a zero-copy view.
//! - `reencode baseline`: the pre-container startup — restore the same
//!   f32 tensors, then transpose + MX-encode every forward weight site
//!   (exactly what the first forward pass pays without a seeded cache).
//!
//! Bitwise parity between the mapped operands and a fresh encode is
//! asserted before any timing. Results go to
//! `BENCH_container_load.json` at the repo root; `MXSTAB_BENCH_SMOKE=1`
//! shrinks the model for CI, the full run uses `lm_olmo_12m` (the
//! ISSUE's acceptance workload).

use mxstab::bench::{jnum, smoke_mode, write_json, Bencher};
use mxstab::formats::container::MxcFile;
use mxstab::formats::gemm::{transpose, PackedMatrix};
use mxstab::formats::spec::{Fmt, FormatId};
use mxstab::runtime::native::NativeEngine;
use mxstab::runtime::{pack_to_container, Backend, Engine};
use mxstab::util::json::Json;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::default();
    b.warmup = 2;
    let (engine, bundle) = if smoke_mode() {
        (NativeEngine::with_batch(2)?, "lm_L1_D32_H1_T32_V64")
    } else {
        (NativeEngine::new(), "lm_olmo_12m")
    };
    let model = engine.load(bundle)?;
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);

    // Pack once: a seed-0 init exported exactly as `mxstab pack` would.
    let state0 = model.init(0, 0.0, 1.0)?;
    let tensors = model.snapshot(&state0)?;
    drop(state0);
    let path =
        std::env::temp_dir().join(format!("mxstab_bench_container_{}.mxc", std::process::id()));
    let file_bytes = pack_to_container(model.as_ref(), &tensors, &fmt, &path)?;
    let sites = model.pack_sites();
    println!(
        "== container load vs f32 re-encode ({bundle}, {} params, {} sites, {:.2} MiB) ==\n",
        model.n_params(),
        sites.len(),
        file_bytes as f64 / (1 << 20) as f64
    );

    // Parity before timing: every mapped operand must be bitwise the
    // operand a fresh encode builds — speed means nothing otherwise.
    {
        let mxc = MxcFile::open(&path)?;
        mxc.verify()?;
        for (i, site) in sites.iter().enumerate() {
            let w = &tensors[site.tensor][site.offset..site.offset + site.k * site.n];
            let wt = transpose(w, site.k, site.n);
            let fresh =
                PackedMatrix::encode_geom(&wt, site.n, site.k, fmt.w_fwd, fmt.scale_bump, fmt.geom);
            let mapped = mxc.site_matrix(i);
            assert!(
                mapped.rows == fresh.rows && mapped.cols == fresh.cols && mapped.data == fresh.data,
                "mapped operand diverged from a fresh encode at site {} ({})",
                i,
                site.name
            );
        }
        println!("parity: all {} mapped operands bitwise-equal to fresh encodes\n", sites.len());
    }

    // O(header) open: parse + validate + map, data region untouched.
    let r_open = b.run("container/open", || {
        let mxc = MxcFile::open(&path).unwrap();
        std::hint::black_box(mxc.meta().sites.len());
    });
    println!("{}", r_open.report_line("(O(header): map + validate, no decode)"));

    // Full container startup: the `--weights model.mxc` path.
    let r_load = b.run("container/open+load_weights", || {
        let mxc = MxcFile::open(&path).unwrap();
        let s = model.load_weights(&mxc).unwrap();
        std::hint::black_box(&s);
    });
    println!("{}", r_load.report_line("(restore tensors + seed packed operands)"));

    // Baseline: pre-container startup from host f32 tensors — restore,
    // then transpose + encode every forward weight operand.
    let r_base = b.run("baseline/restore+reencode", || {
        let s = model.restore(tensors.clone()).unwrap();
        for site in &sites {
            let w = &tensors[site.tensor][site.offset..site.offset + site.k * site.n];
            let wt = transpose(std::hint::black_box(w), site.k, site.n);
            let mat =
                PackedMatrix::encode_geom(&wt, site.n, site.k, fmt.w_fwd, fmt.scale_bump, fmt.geom);
            std::hint::black_box(&mat);
        }
        std::hint::black_box(&s);
    });
    println!("{}", r_base.report_line("(restore tensors + f32 re-encode all sites)"));

    let speedup = r_base.mean_s / r_load.mean_s;
    let report = Json::obj(vec![
        ("bench", Json::from("container_load")),
        ("schema", Json::Num(1.0)),
        ("measured", Json::Bool(true)),
        ("smoke_mode", Json::Bool(smoke_mode())),
        ("workload", Json::from(bundle)),
        ("n_params", Json::Num(model.n_params() as f64)),
        ("n_sites", Json::Num(sites.len() as f64)),
        ("container_bytes", Json::Num(file_bytes as f64)),
        (
            "baseline_note",
            Json::from(
                "baseline is the pre-container startup: restore the same f32 tensors, then \
                 transpose + MX-encode every forward weight site; container rows open the \
                 .mxc (O(header)) and seed the pre-packed operands zero-copy, measured in \
                 this same run on this same machine",
            ),
        ),
        (
            "headline",
            Json::obj(vec![
                ("load_speedup_vs_reencode", jnum(speedup)),
                ("open_ms", jnum(r_open.mean_s * 1e3)),
                ("load_ms", jnum(r_load.mean_s * 1e3)),
                ("reencode_ms", jnum(r_base.mean_s * 1e3)),
            ]),
        ),
        (
            "rows",
            Json::Arr(vec![
                Json::obj(vec![
                    ("name", Json::from("container/open")),
                    ("mean_ms", jnum(r_open.mean_s * 1e3)),
                    ("p95_ms", jnum(r_open.p95_s * 1e3)),
                ]),
                Json::obj(vec![
                    ("name", Json::from("container/open+load_weights")),
                    ("mean_ms", jnum(r_load.mean_s * 1e3)),
                    ("p95_ms", jnum(r_load.p95_s * 1e3)),
                ]),
                Json::obj(vec![
                    ("name", Json::from("baseline/restore+reencode")),
                    ("mean_ms", jnum(r_base.mean_s * 1e3)),
                    ("p95_ms", jnum(r_base.p95_s * 1e3)),
                ]),
            ]),
        ),
    ]);
    let out = write_json("BENCH_container_load.json", &report)?;
    let _ = std::fs::remove_file(&path);
    println!("\nwrote {}", out.display());
    println!(
        "headline: container load {:.3} ms vs f32 re-encode {:.3} ms ({speedup:.2}x), \
         open alone {:.3} ms",
        r_load.mean_s * 1e3,
        r_base.mean_s * 1e3,
        r_open.mean_s * 1e3
    );
    Ok(())
}
