//! L1 bench: MX quantize→dequantize throughput.
//!
//! Compares the pure-rust mirror against the compiled Pallas/HLO kernel
//! (PJRT CPU) across element formats and input distributions, reporting
//! per-iteration latency and effective GB/s. (interpret=True Pallas on CPU
//! measures the *emulation* path — TPU projections live in DESIGN.md §Perf.)

use mxstab::bench::Bencher;
use mxstab::formats::spec::FormatId;
use mxstab::formats::{mx_qdq, quant};
use mxstab::runtime::{Quantizer, Session};
use mxstab::util::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let b = Bencher::default();
    println!("== quantizer benchmarks ==\n");

    let mut rng = Xoshiro256::seed_from(0);
    for &n in &[4096usize, 65536, 1 << 20] {
        let x = rng.normal_vec(n);
        let bytes = (n * 4) as f64;
        for id in [FormatId::E4M3, FormatId::E5M2, FormatId::E2M3, FormatId::E3M2, FormatId::Bf16] {
            let r = b.run(&format!("rust/{}/{}", id.name(), n), || {
                std::hint::black_box(mx_qdq(std::hint::black_box(&x), id, false));
            });
            println!("{}", r.report_line(&format!("{:.2} GB/s", bytes / r.mean_s / 1e9)));
        }
    }

    // In-place variant (the hot path used by analytics).
    let mut buf = rng.normal_vec(1 << 20);
    let f = FormatId::E4M3.elem().unwrap();
    let r = b.run("rust/e4m3/inplace/1M", || {
        quant::mx_qdq_slice(std::hint::black_box(&mut buf), &f, 0);
    });
    println!("{}", r.report_line(&format!("{:.2} GB/s", (buf.len() * 4) as f64 / r.mean_s / 1e9)));

    if artifacts.join("quantizer/manifest.json").exists() {
        let session = Session::cpu()?;
        let q = Quantizer::load(session, &artifacts.join("quantizer"))?;
        let x = rng.normal_vec(q.rows * q.cols);
        let bytes = (x.len() * 4) as f64;
        println!();
        for id in [FormatId::E4M3, FormatId::E5M2, FormatId::Bf16] {
            let r = b.run(&format!("hlo-pallas/{}/{}", id.name(), x.len()), || {
                std::hint::black_box(q.qdq(&x, id as u8 as f32, 0.0).unwrap());
            });
            println!("{}", r.report_line(&format!("{:.2} GB/s", bytes / r.mean_s / 1e9)));
        }
    } else {
        println!("\n(artifacts missing — skipping HLO kernel benches; run `make artifacts`)");
    }
    Ok(())
}
