//! L1 bench: MX quantize→dequantize and matvec throughput, with
//! machine-readable output.
//!
//! Compares three implementations of the same bit-exact semantics:
//!   1. `mx_qdq`        — the scalar reference oracle (allocates, single
//!                        thread, per-element band math),
//!   2. packed codec    — `QdqScratch::qdq_into` (LUT codes + shared-scale
//!                        exponents, pool-parallel, allocation-free), plus
//!                        the split encode (`PackedVec::encode`) / decode
//!                        (`decode_into`) halves,
//!   3. (with `--features xla` + artifacts) the compiled Pallas/HLO kernel
//!       via PJRT CPU — the *emulation* path; TPU projections live in
//!       DESIGN.md §Perf.
//!
//! The packed/scalar ratio at n = 2^20 is the headline number the repo's
//! acceptance bar tracks (≥5× on a multicore host); bitwise equality of
//! the two paths is asserted here before timing and property-tested in
//! `tests/packed_roundtrip.rs`. Results are serialized to
//! `BENCH_quantizer.json` at the repo root (per-format encode/decode/qdq
//! MB/s + the headline before/after ratio vs the scalar reference).
//! `MXSTAB_BENCH_SMOKE=1` shrinks the sizes for CI.

use mxstab::bench::{jnum, smoke_mode, write_json, Bencher};
use mxstab::formats::kernel::{self, Tier};
use mxstab::formats::spec::{BlockGeom, FormatId, BLOCK_SIZES};
use mxstab::formats::{
    dot, gemm, mx_qdq, packed_qdq, set_unpacked_subbyte_storage, PackedMatrix, PackedVec,
    QdqScratch,
};
use mxstab::util::json::Json;
use mxstab::util::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    let b = Bencher::default();
    println!("== quantizer benchmarks ==\n");
    println!("kernel: {} (isa: {})\n", kernel::describe(), kernel::isa_name());

    let mut rng = Xoshiro256::seed_from(0);
    let formats = [
        FormatId::E4M3,
        FormatId::E5M2,
        FormatId::E2M3,
        FormatId::E3M2,
        FormatId::E2M1,
        FormatId::Int4,
    ];
    let sizes: &[usize] = if smoke_mode() { &[4096] } else { &[4096, 65536, 1 << 20] };

    let mut qdq_rows = Vec::new();
    for &n in sizes {
        let x = rng.normal_vec(n);
        let bytes = (n * 4) as f64;
        let mut out = vec![0.0f32; n];
        let mut scratch = QdqScratch::new();
        for id in formats {
            // Cross-check before timing: the packed path must be bitwise
            // identical to the scalar oracle on this exact input.
            let (want, cw) = mx_qdq(&x, id, false);
            let (got, cg) = packed_qdq(&x, id, false);
            assert_eq!(cw, cg, "{id:?}: clamp count diverged");
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{id:?}: packed path diverged from mx_qdq at n={n}"
            );

            let rs = b.run(&format!("scalar/{}/{}", id.name(), n), || {
                std::hint::black_box(mx_qdq(std::hint::black_box(&x), id, false));
            });
            println!("{}", rs.report_line(&format!("{:.2} GB/s", bytes / rs.mean_s / 1e9)));
            let rp = b.run(&format!("packed/{}/{}", id.name(), n), || {
                scratch.qdq_into(std::hint::black_box(&x), &mut out, id, false);
                std::hint::black_box(&out);
            });
            println!(
                "{}",
                rp.report_line(&format!(
                    "{:.2} GB/s  [{:.1}x vs scalar]",
                    bytes / rp.mean_s / 1e9,
                    rs.mean_s / rp.mean_s
                ))
            );
            // Split halves: encode-only and decode-only throughput.
            let re = b.run(&format!("encode/{}/{}", id.name(), n), || {
                std::hint::black_box(PackedVec::encode(std::hint::black_box(&x), id, false));
            });
            let pv = PackedVec::encode(&x, id, false);
            let rd = b.run(&format!("decode/{}/{}", id.name(), n), || {
                pv.decode_into(&mut out);
                std::hint::black_box(&out);
            });
            qdq_rows.push(Json::obj(vec![
                ("format", Json::from(id.name())),
                ("n", Json::Num(n as f64)),
                ("qdq_mb_per_s", jnum(bytes / rp.mean_s / 1e6)),
                ("encode_mb_per_s", jnum(bytes / re.mean_s / 1e6)),
                ("decode_mb_per_s", jnum(bytes / rd.mean_s / 1e6)),
                ("scalar_mb_per_s", jnum(bytes / rs.mean_s / 1e6)),
                ("speedup_vs_scalar", jnum(rs.mean_s / rp.mean_s)),
            ]));
        }
        // bf16 has no packed form; keep the scalar number for context.
        let r = b.run(&format!("scalar/bf16/{}", n), || {
            std::hint::black_box(mx_qdq(std::hint::black_box(&x), FormatId::Bf16, false));
        });
        println!("{}", r.report_line(&format!("{:.2} GB/s", bytes / r.mean_s / 1e9)));
        println!();
    }

    // Headline number: packed codec vs scalar mx_qdq at the largest size,
    // e4m3 (n = 2^20 in full mode), plus the SIMD codec vs the panel
    // tier's scalar codec on the same input.
    let headline = {
        let n = *sizes.last().unwrap();
        let x = rng.normal_vec(n);
        let mut out = vec![0.0f32; n];
        let mut scratch = QdqScratch::new();
        let rs = b.run("headline/scalar/e4m3", || {
            std::hint::black_box(mx_qdq(std::hint::black_box(&x), FormatId::E4M3, false));
        });
        let rp = b.run("headline/packed/e4m3", || {
            scratch.qdq_into(std::hint::black_box(&x), &mut out, FormatId::E4M3, false);
            std::hint::black_box(&out);
        });
        kernel::force_tier(Some(Tier::Panel));
        let rpanel = b.run("headline/packed-scalar-codec/e4m3", || {
            scratch.qdq_into(std::hint::black_box(&x), &mut out, FormatId::E4M3, false);
            std::hint::black_box(&out);
        });
        kernel::force_tier(None);
        println!(
            "headline: packed codec is {:.1}x the scalar mx_qdq at n={n} \
             (scalar {:.3} ms, packed {:.3} ms; simd codec {:.2}x the scalar-codec tier)\n",
            rs.mean_s / rp.mean_s,
            rs.mean_s * 1e3,
            rp.mean_s * 1e3,
            rpanel.mean_s / rp.mean_s
        );
        Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("scalar_ms", jnum(rs.mean_s * 1e3)),
            ("packed_ms", jnum(rp.mean_s * 1e3)),
            ("speedup_vs_scalar", jnum(rs.mean_s / rp.mean_s)),
            ("scalar_codec_tier_ms", jnum(rpanel.mean_s * 1e3)),
            ("simd_codec_speedup_vs_scalar_tier", jnum(rpanel.mean_s / rp.mean_s)),
        ])
    };

    // Storage density: effective bytes per element for every format ×
    // block geometry (exact, from the encoded buffers — not timed). The
    // acceptance bar for 4-bit formats is ≤ 0.6 bytes/elem at block 32.
    let storage_rows = {
        let n = 1 << 14;
        let x = rng.normal_vec(n);
        let mut rows = Vec::new();
        println!("-- storage density (bytes per element) --");
        for id in formats {
            for &bs in &BLOCK_SIZES {
                for two_level in [false, true] {
                    let geom = BlockGeom::new(bs, two_level);
                    let p = PackedVec::encode_geom(&x, id, false, geom);
                    let bpe = p.bytes() as f64 / n as f64;
                    if id.code_bits() == 4 {
                        // One-level bs16 pays 2 scale bytes per 16 elems
                        // (0.625 exactly) — the fine-granularity overhead
                        // the block-size axis exists to measure.
                        let bar = if bs == 16 && !two_level { 0.65 } else { 0.6 };
                        assert!(
                            bpe <= bar,
                            "{id:?} bs{bs} 2lvl={two_level}: {bpe} bytes/elem > {bar}"
                        );
                    }
                    rows.push(Json::obj(vec![
                        ("format", Json::from(id.name())),
                        ("block_size", Json::Num(bs as f64)),
                        ("two_level", Json::Bool(two_level)),
                        ("code_bits", Json::Num(id.code_bits() as f64)),
                        ("bytes_per_elem", jnum(bpe)),
                    ]));
                    if !two_level {
                        println!("  {:>5} bs{:<2}  {:.4} B/elem", id.name(), bs, bpe);
                    }
                }
            }
        }
        println!();
        Json::Arr(rows)
    };

    // Sub-byte decode: nibble-packed (two codes per byte, decode4 kernel)
    // vs byte-expanded storage of the same FP4 data — the decode-MB/s
    // cost/benefit of halving the code bytes.
    let subbyte = {
        let n = *sizes.last().unwrap();
        let x = rng.normal_vec(n);
        let bytes = (n * 4) as f64;
        let mut out = vec![0.0f32; n];
        let mut rows = Vec::new();
        for id in [FormatId::E2M1, FormatId::Int4] {
            let p4 = PackedVec::encode(&x, id, false);
            assert!(p4.packed4(), "{id:?} must default to nibble storage");
            set_unpacked_subbyte_storage(true);
            let p8 = PackedVec::encode(&x, id, false);
            set_unpacked_subbyte_storage(false);
            assert!(!p8.packed4());
            // Both storages must decode to identical bits before timing.
            let (d4, d8) = (p4.decode(), p8.decode());
            assert!(
                d4.iter().zip(&d8).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{id:?}: nibble and byte storage decode diverged"
            );
            let r4 = b.run(&format!("decode-packed4/{}/{}", id.name(), n), || {
                p4.decode_into(&mut out);
                std::hint::black_box(&out);
            });
            let r8 = b.run(&format!("decode-packed8/{}/{}", id.name(), n), || {
                p8.decode_into(&mut out);
                std::hint::black_box(&out);
            });
            println!(
                "subbyte decode {}: packed4 {:.2} GB/s vs packed8 {:.2} GB/s ({:.2}x)",
                id.name(),
                bytes / r4.mean_s / 1e9,
                bytes / r8.mean_s / 1e9,
                r8.mean_s / r4.mean_s
            );
            rows.push(Json::obj(vec![
                ("format", Json::from(id.name())),
                ("n", Json::Num(n as f64)),
                ("packed4_decode_mb_per_s", jnum(bytes / r4.mean_s / 1e6)),
                ("packed8_decode_mb_per_s", jnum(bytes / r8.mean_s / 1e6)),
                ("packed4_vs_packed8", jnum(r8.mean_s / r4.mean_s)),
                ("packed4_bytes_per_elem", jnum(p4.bytes() as f64 / n as f64)),
                ("packed8_bytes_per_elem", jnum(p8.bytes() as f64 / n as f64)),
            ]));
        }
        println!();
        Json::Arr(rows)
    };

    // Matvec: allocation-per-row scalar reference vs the packed engine.
    let matvec_rows = {
        let (rows, cols) = if smoke_mode() { (64, 512) } else { (256, 4096) };
        let a = rng.normal_vec(rows * cols);
        let x = rng.normal_vec(cols);
        let flops = (2 * rows * cols) as f64;
        let rr = b.run(&format!("matvec/scalar-ref/{rows}x{cols}"), || {
            std::hint::black_box(dot::mx_matvec_ref(&a, rows, cols, &x, FormatId::E4M3));
        });
        println!("{}", rr.report_line(&format!("{:.2} GFLOP/s(emu)", flops / rr.mean_s / 1e9)));
        let rp = b.run(&format!("matvec/packed/{rows}x{cols}"), || {
            std::hint::black_box(dot::mx_matvec(&a, rows, cols, &x, FormatId::E4M3));
        });
        println!(
            "{}",
            rp.report_line(&format!(
                "{:.2} GFLOP/s(emu)  [{:.1}x vs scalar-ref]",
                flops / rp.mean_s / 1e9,
                rr.mean_s / rp.mean_s
            ))
        );
        // Steady-state: operands pre-encoded once (the sweep-loop shape).
        let am = PackedMatrix::encode(&a, rows, cols, FormatId::E4M3, false);
        let xv = PackedVec::encode(&x, FormatId::E4M3, false);
        let re = b.run(&format!("matvec/packed-preenc/{rows}x{cols}"), || {
            std::hint::black_box(gemm::matvec(&am, &xv));
        });
        println!("{}", re.report_line(&format!("{:.2} GFLOP/s(emu)", flops / re.mean_s / 1e9)));
        println!();
        Json::Arr(vec![
            Json::obj(vec![
                ("name", Json::from(format!("matvec/{rows}x{cols}"))),
                ("gflops", jnum(flops / rp.mean_s / 1e9)),
                ("preencoded_gflops", jnum(flops / re.mean_s / 1e9)),
                ("scalar_ref_gflops", jnum(flops / rr.mean_s / 1e9)),
                ("speedup_vs_scalar", jnum(rr.mean_s / rp.mean_s)),
            ]),
        ])
    };

    let report = Json::obj(vec![
        ("bench", Json::from("quantizer")),
        ("schema", Json::Num(3.0)),
        ("measured", Json::Bool(true)),
        ("smoke_mode", Json::Bool(smoke_mode())),
        ("pool_parallelism", Json::Num(mxstab::util::pool::parallelism() as f64)),
        ("kernel", Json::from(kernel::describe())),
        ("kernel_isa", Json::from(kernel::isa_name())),
        ("headline", headline),
        ("qdq", Json::Arr(qdq_rows)),
        ("storage", storage_rows),
        ("subbyte_decode", subbyte),
        ("matvec", matvec_rows),
    ]);
    let path = write_json("BENCH_quantizer.json", &report)?;
    println!("wrote {}", path.display());

    #[cfg(feature = "xla")]
    bench_hlo_kernel(&b, &mut rng)?;
    #[cfg(not(feature = "xla"))]
    println!("(built without `xla` — skipping HLO/PJRT kernel benches)");
    Ok(())
}

/// The compiled Pallas/HLO quantizer through PJRT (needs `make artifacts`).
#[cfg(feature = "xla")]
fn bench_hlo_kernel(b: &Bencher, rng: &mut Xoshiro256) -> anyhow::Result<()> {
    use mxstab::runtime::{Quantizer, Session};
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("quantizer/manifest.json").exists() {
        println!("(artifacts missing — skipping HLO kernel benches; run `make artifacts`)");
        return Ok(());
    }
    let session = Session::cpu()?;
    let q = Quantizer::load(session, &artifacts.join("quantizer"))?;
    let x = rng.normal_vec(q.rows * q.cols);
    let bytes = (x.len() * 4) as f64;
    println!();
    for id in [FormatId::E4M3, FormatId::E5M2, FormatId::Bf16] {
        let r = b.run(&format!("hlo-pallas/{}/{}", id.name(), x.len()), || {
            std::hint::black_box(q.qdq(&x, id as u8 as f32, 0.0).unwrap());
        });
        println!("{}", r.report_line(&format!("{:.2} GB/s", bytes / r.mean_s / 1e9)));
    }
    Ok(())
}
