//! L3 bench: end-to-end training-step throughput, with machine-readable
//! output.
//!
//! Every section measures the current execution layer (panel-decoded GEMM
//! kernels, persistent worker pool, step-scoped operand cache) *and* the
//! pre-PR baseline path in the same run — the row-wise LUT kernel
//! ([`gemm_ref`]) with per-call thread spawns and the operand cache
//! disabled — so the before/after speedup is measured on the same
//! machine, same build, same inputs. Bitwise parity between the two GEMM
//! paths is asserted before any timing.
//!
//! Results are printed human-readably and serialized to
//! `BENCH_step_throughput.json` at the repo root (headline GEMM GFLOP/s +
//! speedup, backward-GEMM rows, per-workload native step ms for the proxy
//! and the transformer LM). `MXSTAB_BENCH_SMOKE=1` shrinks every shape
//! for CI; `MXSTAB_BENCH_BUDGET_MS` bounds per-row time.
//!
//! With `--features xla` + artifacts, compiled-bundle step throughput is
//! also reported (not part of the JSON — PJRT numbers depend on external
//! artifacts).

use mxstab::bench::{jnum, smoke_mode, write_json, Bencher};
use mxstab::formats::gemm::{gemm, gemm_ref, set_reference_kernel, PackedMatrix};
use mxstab::formats::kernel::{self, Tier};
use mxstab::formats::spec::{Fmt, FormatId};
use mxstab::runtime::native::NativeEngine;
use mxstab::runtime::{Backend, Engine, StepArgs};
use mxstab::util::json::Json;
use mxstab::util::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::default();
    b.warmup = 2;
    println!("kernel: {} (isa: {})\n", kernel::describe(), kernel::isa_name());

    let (gemm_rows, gemm_headline, gemm_vs_panel) = bench_gemm(&b);
    let bwd_rows = bench_backward_gemm(&b);
    let proxy_rows = bench_native_step(&b)?;
    let (lm_rows, lm_headline, lm_vs_panel) = bench_native_lm_step(&b)?;

    let report = Json::obj(vec![
        ("bench", Json::from("step_throughput")),
        ("schema", Json::Num(3.0)),
        ("measured", Json::Bool(true)),
        ("smoke_mode", Json::Bool(smoke_mode())),
        ("pool_parallelism", Json::Num(mxstab::util::pool::parallelism() as f64)),
        ("kernel", Json::from(kernel::describe())),
        ("kernel_isa", Json::from(kernel::isa_name())),
        (
            "baseline_note",
            Json::from(
                "baseline_* fields are the pre-panel execution path (row-wise LUT GEMM kernel, \
                 per-call std::thread::scope fan-out, operand cache disabled) and panel_* \
                 fields the PR-4 panel tier (scalar inner loops, cache on), both measured in \
                 this same run on this same machine; the default rows run the SIMD tier where \
                 the machine has one",
            ),
        ),
        (
            "headline",
            Json::obj(vec![
                ("gemm_speedup_vs_baseline", jnum(gemm_headline)),
                ("gemm_simd_speedup_vs_panel", jnum(gemm_vs_panel)),
                ("lm_step_speedup_vs_baseline", jnum(lm_headline)),
                ("lm_step_simd_speedup_vs_panel", jnum(lm_vs_panel)),
            ]),
        ),
        ("gemm", gemm_rows),
        ("backward_gemm", bwd_rows),
        ("native_step", proxy_rows),
        ("native_lm_step", lm_rows),
    ]);
    let path = write_json("BENCH_step_throughput.json", &report)?;
    println!("wrote {}", path.display());
    println!(
        "headline: packed GEMM {gemm_headline:.2}x vs baseline ({gemm_vs_panel:.2}x vs panel \
         tier), native LM step {lm_headline:.2}x vs baseline ({lm_vs_panel:.2}x vs panel tier)"
    );

    #[cfg(feature = "xla")]
    bench_bundles(&b)?;
    #[cfg(not(feature = "xla"))]
    println!("(built without `xla` — skipping compiled-bundle step benches)");
    Ok(())
}

/// Forward-GEMM throughput: the active (SIMD) kernel vs the PR-4 panel
/// tier vs the row-wise baseline at the paper's proxy/LM layer shapes.
/// Returns (rows, headline speedup vs baseline, headline speedup vs the
/// panel tier — both at the largest e4m3 shape).
fn bench_gemm(b: &Bencher) -> (Json, f64, f64) {
    println!("== packed MX GEMM throughput (simd vs panel tier vs row-wise baseline) ==\n");
    let mut rng = Xoshiro256::seed_from(0);
    // (m, n, k): proxy-MLP layer, LM attention-ish block, LM FFN.
    let shapes: &[(usize, usize, usize)] = if smoke_mode() {
        &[(64, 64, 128)]
    } else {
        &[(128, 128, 512), (256, 256, 1024), (512, 2048, 512)]
    };
    let mut rows = Vec::new();
    let mut headline = 0.0f64;
    let mut headline_panel = 0.0f64;
    for &(m, n, k) in shapes {
        let a = rng.normal_vec(m * k);
        let w = rng.normal_vec(n * k);
        let flops = (2 * m * n * k) as f64;
        for id in [FormatId::E4M3, FormatId::E5M2] {
            // Steady-state shape: weights stay packed across steps,
            // activations are re-encoded every call (as a step would).
            let wm = PackedMatrix::encode(&w, n, k, id, false);
            let am = PackedMatrix::encode(&a, m, k, id, false);
            let mut c_new = vec![0.0f32; m * n];
            let mut c_ref = vec![0.0f32; m * n];
            gemm(&am, &wm, &mut c_new);
            gemm_ref(&am, &wm, &mut c_ref);
            assert!(
                c_new.iter().zip(&c_ref).all(|(x, y)| x.to_bits() == y.to_bits()),
                "active kernel tier diverged from the reference at {m}x{n}x{k} {id:?}"
            );
            let name = format!("gemm/{}/{}x{}x{}", id.name(), m, n, k);
            let r_new = b.run(&name, || {
                let am = PackedMatrix::encode(std::hint::black_box(&a), m, k, id, false);
                gemm(&am, &wm, &mut c_new);
                std::hint::black_box(&c_new);
            });
            kernel::force_tier(Some(Tier::Panel));
            let r_panel = b.run(&format!("{name}/panel"), || {
                let am = PackedMatrix::encode(std::hint::black_box(&a), m, k, id, false);
                gemm(&am, &wm, &mut c_new);
                std::hint::black_box(&c_new);
            });
            // Baseline = the pre-panel path end to end: scalar tier so
            // the timed activation encode uses the scalar codec too.
            kernel::force_tier(Some(Tier::Scalar));
            let r_ref = b.run(&format!("{name}/baseline"), || {
                let am = PackedMatrix::encode(std::hint::black_box(&a), m, k, id, false);
                gemm_ref(&am, &wm, &mut c_ref);
                std::hint::black_box(&c_ref);
            });
            kernel::force_tier(None);
            let speedup = r_ref.mean_s / r_new.mean_s;
            let vs_panel = r_panel.mean_s / r_new.mean_s;
            let gflops = flops / r_new.mean_s / 1e9;
            println!(
                "{}",
                r_new.report_line(&format!(
                    "{gflops:.2} GFLOP/s(emu)  [{speedup:.2}x vs row-wise, \
                     {vs_panel:.2}x vs panel tier]"
                ))
            );
            rows.push(Json::obj(vec![
                ("name", Json::from(name)),
                ("shape", Json::from(format!("{m}x{n}x{k}"))),
                ("format", Json::from(id.name())),
                ("mean_ms", jnum(r_new.mean_s * 1e3)),
                ("gflops", jnum(gflops)),
                ("panel_mean_ms", jnum(r_panel.mean_s * 1e3)),
                ("simd_speedup_vs_panel", jnum(vs_panel)),
                ("baseline_mean_ms", jnum(r_ref.mean_s * 1e3)),
                ("baseline_gflops", jnum(flops / r_ref.mean_s / 1e9)),
                ("speedup_vs_baseline", jnum(speedup)),
            ]));
            if id == FormatId::E4M3 {
                headline = speedup; // largest e4m3 shape wins (shapes ascend)
                headline_panel = vs_panel;
            }
        }
    }
    println!();
    (Json::Arr(rows), headline, headline_panel)
}

/// The backward-GEMM hot path: weight gradients re-block both operands
/// along the batch axis (transposed encode), and the paper's MX-mix runs
/// E4M3 activations against E5M2 gradients in one GEMM.
fn bench_backward_gemm(b: &Bencher) -> Json {
    println!("== backward GEMM (transposed re-encode + mixed formats) ==\n");
    let mut rng = Xoshiro256::seed_from(1);
    // dW = Xᵀ·G at the proxy shape: batch 256, D 256, H 1024.
    let (batch, d, h) =
        if smoke_mode() { (64usize, 64usize, 128usize) } else { (256, 256, 1024) };
    let x = rng.normal_vec(batch * d);
    let g = rng.normal_vec(batch * h);
    let flops = (2 * d * h * batch) as f64;
    let mut rows = Vec::new();
    for (label, xa_id, g_id) in [
        ("e4m3xe4m3", FormatId::E4M3, FormatId::E4M3),
        ("e4m3xe5m2", FormatId::E4M3, FormatId::E5M2),
    ] {
        let mut dw = vec![0.0f32; d * h];
        let name = format!("dw-gemm/{label}/{d}x{h}x{batch}");
        // Both operands re-encode per call with blocks along the batch
        // axis — exactly what the native backward does every step.
        let r_new = b.run(&name, || {
            let xt = PackedMatrix::encode_t(std::hint::black_box(&x), batch, d, xa_id, false);
            let gt = PackedMatrix::encode_t(std::hint::black_box(&g), batch, h, g_id, false);
            gemm(&xt, &gt, &mut dw);
            std::hint::black_box(&dw);
        });
        // Scalar tier: the baseline's transposed re-encodes must use the
        // pre-panel scalar codec, not the SIMD one.
        kernel::force_tier(Some(Tier::Scalar));
        let r_ref = b.run(&format!("{name}/baseline"), || {
            let xt = PackedMatrix::encode_t(std::hint::black_box(&x), batch, d, xa_id, false);
            let gt = PackedMatrix::encode_t(std::hint::black_box(&g), batch, h, g_id, false);
            gemm_ref(&xt, &gt, &mut dw);
            std::hint::black_box(&dw);
        });
        kernel::force_tier(None);
        let speedup = r_ref.mean_s / r_new.mean_s;
        println!(
            "{}",
            r_new.report_line(&format!(
                "{:.2} GFLOP/s(emu)  [{speedup:.2}x vs row-wise]",
                flops / r_new.mean_s / 1e9
            ))
        );
        rows.push(Json::obj(vec![
            ("name", Json::from(name)),
            ("mean_ms", jnum(r_new.mean_s * 1e3)),
            ("gflops", jnum(flops / r_new.mean_s / 1e9)),
            ("baseline_mean_ms", jnum(r_ref.mean_s * 1e3)),
            ("speedup_vs_baseline", jnum(speedup)),
        ]));
    }
    println!();
    Json::Arr(rows)
}

/// One timed native-step loop; `baseline` routes GEMMs through the
/// row-wise reference kernel and disables the operand cache (the
/// pre-panel execution path); `tier` forces a kernel tier for the loop
/// (e.g. `Tier::Panel` = the PR-4 execution layer, cache on).
fn time_steps(
    b: &Bencher,
    model: &mxstab::runtime::native::NativeModel,
    label: &str,
    fmt: &Fmt,
    tokens: Option<&dyn Fn(i32) -> Vec<i32>>,
    baseline: bool,
    tier: Option<Tier>,
) -> anyhow::Result<mxstab::bench::BenchResult> {
    let state0 = model.init(0, 0.0, 1.0)?;
    state0.exec.set_enabled(!baseline);
    set_reference_kernel(baseline);
    kernel::force_tier(tier);
    let mut state = Some(state0);
    let mut step = 0i32;
    let r = b.run(label, || {
        let args = StepArgs {
            tokens: tokens.map(|f| f(step)),
            fmt: fmt.to_vec(),
            hyper: vec![5e-4, 0.0, 0.0, 1e-3],
            seed: 0,
            step,
        };
        let (s2, m) = model.step(state.take().unwrap(), &args).unwrap();
        std::hint::black_box(m);
        state = Some(s2);
        step += 1;
    });
    kernel::force_tier(None);
    set_reference_kernel(false);
    Ok(r)
}

/// Full native training step (teacher fwd + student fwd + bwd + Adam +
/// metrics) at the proxy anchor shape, per precision scheme, new vs
/// baseline execution path.
fn bench_native_step(b: &Bencher) -> anyhow::Result<Json> {
    println!("== native training-step throughput (pure rust) ==\n");
    let (batch, bundle) = if smoke_mode() {
        (64usize, "proxy_gelu_ln_L2_D64")
    } else {
        (256, "proxy_gelu_ln_L4_D256")
    };
    let engine = NativeEngine::with_batch(batch)?;
    let model = engine.load(bundle)?;
    let n_params = model.n_params() as f64;
    let schemes = [
        ("fp32", Fmt::fp32()),
        ("e4m3-full", Fmt::full(FormatId::E4M3, FormatId::E4M3)),
        ("e4m3-bf16act", Fmt::bf16_act(FormatId::E4M3)),
        ("e4m3-fwdonly", Fmt::fwd_only(FormatId::E4M3, FormatId::E4M3)),
        ("e2m1-full", Fmt::full(FormatId::E2M1, FormatId::E2M1)),
    ];
    let mut rows = Vec::new();
    for (label, fmt) in &schemes {
        let name = format!("native/{}/{label}", model.name());
        let r_new = time_steps(b, model.as_ref(), &name, fmt, None, false, None)?;
        // Baseline = pre-panel path end to end: scalar tier (row-wise
        // GEMM + scalar codec/optimizer/LN) with the cache off.
        let r_ref = time_steps(
            b,
            model.as_ref(),
            &format!("{name}/baseline"),
            fmt,
            None,
            true,
            Some(Tier::Scalar),
        )?;
        // 6·N·batch FLOPs per step (fwd + bwd over N params, batch rows).
        let flops = 6.0 * n_params * batch as f64;
        let speedup = r_ref.mean_s / r_new.mean_s;
        println!(
            "{}",
            r_new.report_line(&format!(
                "{:.1} steps/s  {:.2} GFLOP/s(emu)  [{speedup:.2}x vs baseline]",
                1.0 / r_new.mean_s,
                flops / r_new.mean_s / 1e9
            ))
        );
        rows.push(Json::obj(vec![
            ("name", Json::from(name)),
            ("scheme", Json::from(*label)),
            ("step_ms", jnum(r_new.mean_s * 1e3)),
            ("steps_per_s", jnum(1.0 / r_new.mean_s)),
            ("baseline_step_ms", jnum(r_ref.mean_s * 1e3)),
            ("speedup_vs_baseline", jnum(speedup)),
        ]));
    }
    println!();
    Ok(Json::Arr(rows))
}

/// Full native transformer-LM training step (corpus batch + fwd + bwd +
/// Adam + metrics), per precision scheme: active (SIMD) tier vs the
/// PR-4 panel tier vs the pre-panel baseline path. Returns (rows,
/// headline speedups vs baseline and vs panel under the fully-quantized
/// scheme).
fn bench_native_lm_step(b: &Bencher) -> anyhow::Result<(Json, f64, f64)> {
    use mxstab::coordinator::Sweeper;

    println!("== native LM training-step throughput (pure rust) ==\n");
    let (engine, bundle) = if smoke_mode() {
        (NativeEngine::with_batch(4)?, "lm_L1_D32_H1_T32_V64")
    } else {
        (NativeEngine::new(), "lm_olmo_1m")
    };
    let sweeper = Sweeper::new(engine);
    let runner = sweeper.runner(bundle)?;
    let model = runner.backend.clone();
    let corpus = runner.corpus.clone().expect("LM corpus");
    let n_params = model.n_params() as f64;
    let (batch, len) = model.tokens_shape().expect("LM tokens shape");
    let tokens_per_step = (batch * (len - 1)) as f64;
    let schemes = [
        ("fp32", Fmt::fp32()),
        ("e4m3-full", Fmt::full(FormatId::E4M3, FormatId::E4M3)),
        ("e4m3-bf16act", Fmt::bf16_act(FormatId::E4M3)),
        // Sub-byte storage: FP4 weights/activations, nibble-packed codes.
        ("e2m1-full", Fmt::full(FormatId::E2M1, FormatId::E2M1)),
    ];
    let mut rows = Vec::new();
    let mut headline = 0.0f64;
    let mut headline_panel = 0.0f64;
    for (label, fmt) in &schemes {
        let name = format!("native/{}/{label}", model.name());
        let toks = |step: i32| corpus.batch(0, step as u64, batch, len);
        let r_new = time_steps(b, model.as_ref(), &name, fmt, Some(&toks), false, None)?;
        let r_panel = time_steps(
            b,
            model.as_ref(),
            &format!("{name}/panel"),
            fmt,
            Some(&toks),
            false,
            Some(Tier::Panel),
        )?;
        // Baseline = pre-panel path end to end (scalar tier, cache off).
        let r_ref = time_steps(
            b,
            model.as_ref(),
            &format!("{name}/baseline"),
            fmt,
            Some(&toks),
            true,
            Some(Tier::Scalar),
        )?;
        // 6·N FLOPs per token (fwd + bwd over N params).
        let flops = 6.0 * n_params * tokens_per_step;
        let speedup = r_ref.mean_s / r_new.mean_s;
        let vs_panel = r_panel.mean_s / r_new.mean_s;
        println!(
            "{}",
            r_new.report_line(&format!(
                "{:.2} steps/s  {:.0} tok/s  {:.2} GFLOP/s(emu)  \
                 [{speedup:.2}x vs baseline, {vs_panel:.2}x vs panel tier]",
                1.0 / r_new.mean_s,
                tokens_per_step / r_new.mean_s,
                flops / r_new.mean_s / 1e9
            ))
        );
        rows.push(Json::obj(vec![
            ("name", Json::from(name)),
            ("scheme", Json::from(*label)),
            ("step_ms", jnum(r_new.mean_s * 1e3)),
            ("steps_per_s", jnum(1.0 / r_new.mean_s)),
            ("tokens_per_s", jnum(tokens_per_step / r_new.mean_s)),
            ("panel_step_ms", jnum(r_panel.mean_s * 1e3)),
            ("simd_speedup_vs_panel", jnum(vs_panel)),
            ("baseline_step_ms", jnum(r_ref.mean_s * 1e3)),
            ("speedup_vs_baseline", jnum(speedup)),
        ]));
        if *label == "e4m3-full" {
            headline = speedup;
            headline_panel = vs_panel;
        }
    }
    println!();
    Ok((Json::Arr(rows), headline, headline_panel))
}

#[cfg(feature = "xla")]
fn bench_bundles(b: &Bencher) -> anyhow::Result<()> {
    use mxstab::coordinator::Sweeper;
    use mxstab::runtime::{list_bundles, PjrtEngine, Session};

    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("index.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let session = Session::cpu()?;
    let sweeper = Sweeper::new(PjrtEngine::new(session, &artifacts));

    let schemes = [
        ("fp32", Fmt::fp32()),
        ("e4m3-full", Fmt::full(FormatId::E4M3, FormatId::E4M3)),
        ("e4m3-bf16act", Fmt::bf16_act(FormatId::E4M3)),
        ("e4m3-fwdonly", Fmt::fwd_only(FormatId::E4M3, FormatId::E4M3)),
    ];

    println!("== training-step throughput (PJRT bundles) ==\n");
    let mut names = list_bundles(&artifacts)?;
    names.retain(|n| n != "quantizer" && !n.contains("pallas"));
    names.sort();
    for name in names {
        let runner = match sweeper.runner(&name) {
            Ok(r) => r,
            Err(e) => {
                println!("{name}: load failed: {e:#}");
                continue;
            }
        };
        let bundle = &runner.backend;
        let n_params = bundle.manifest.n_params as f64;
        let tokens = bundle.tokens_shape();
        for (label, fmt) in &schemes {
            let mut state = Some(bundle.init(0, 0.0, 1.0)?);
            let mut step = 0i32;
            let corpus = runner.corpus.clone();
            let r = b.run(&format!("{name}/{label}"), || {
                let toks = match (&corpus, tokens) {
                    (Some(c), Some((bt, l))) => Some(c.batch(0, step as u64, bt, l)),
                    _ => None,
                };
                let args = StepArgs {
                    tokens: toks,
                    fmt: fmt.to_vec(),
                    hyper: vec![5e-4, 0.0, 0.0, 1e-3],
                    seed: 0,
                    step,
                };
                let (s2, m) = bundle.step(state.take().unwrap(), &args).unwrap();
                std::hint::black_box(m);
                state = Some(s2);
                step += 1;
            });
            // 6·N FLOPs per token-equivalent unit: use manifest FLOPs when
            // present (LM), else 6·N·batch for the proxy.
            let flops = bundle.manifest.flops_per_step.map(|f| f as f64).unwrap_or_else(|| {
                let batch = bundle.manifest.cfg_num("batch").unwrap_or(1.0);
                6.0 * n_params * batch
            });
            println!(
                "{}",
                r.report_line(&format!(
                    "{:.1} steps/s  {:.2} GFLOP/s(emu)",
                    1.0 / r.mean_s,
                    flops / r.mean_s / 1e9
                ))
            );
        }
        println!();
    }
    Ok(())
}
