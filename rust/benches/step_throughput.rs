//! L3 bench: end-to-end training-step throughput.
//!
//! Two faces:
//! * Always available — the pure-rust emulated forward pass over the
//!   packed MX engine: per-layer `C = A·Bᵀ` block GEMMs at the paper's
//!   proxy/LM shapes. This is the quantity the packed codec exists to
//!   accelerate and runs on a bare machine.
//! * With `--features xla` + artifacts — real compiled-bundle step
//!   throughput per precision scheme (the quantity behind every sweep's
//!   wallclock). One section per paper workload family (proxy grid, LM
//!   ladder).

use mxstab::bench::Bencher;
use mxstab::formats::gemm::{gemm, PackedMatrix};
use mxstab::formats::spec::FormatId;
use mxstab::util::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::default();
    b.warmup = 2;

    println!("== packed MX GEMM throughput (pure rust, no artifacts) ==\n");
    let mut rng = Xoshiro256::seed_from(0);
    // (m, n, k): proxy-MLP layer, LM attention-ish block, LM FFN.
    for &(m, n, k) in &[(128usize, 128usize, 512usize), (256, 256, 1024), (512, 2048, 512)] {
        let a = rng.normal_vec(m * k);
        let w = rng.normal_vec(n * k);
        let flops = (2 * m * n * k) as f64;
        for id in [FormatId::E4M3, FormatId::E5M2] {
            // Steady-state shape: weights stay packed across steps,
            // activations are re-encoded every call (as a step would).
            let wm = PackedMatrix::encode(&w, n, k, id, false);
            let mut c = vec![0.0f32; m * n];
            let r = b.run(&format!("gemm/{}/{}x{}x{}", id.name(), m, n, k), || {
                let am = PackedMatrix::encode(std::hint::black_box(&a), m, k, id, false);
                gemm(&am, &wm, &mut c);
                std::hint::black_box(&c);
            });
            println!(
                "{}",
                r.report_line(&format!("{:.2} GFLOP/s(emu)", flops / r.mean_s / 1e9))
            );
        }
    }
    println!();

    #[cfg(feature = "xla")]
    bench_bundles(&b)?;
    #[cfg(not(feature = "xla"))]
    println!("(built without `xla` — skipping compiled-bundle step benches)");
    Ok(())
}

#[cfg(feature = "xla")]
fn bench_bundles(b: &Bencher) -> anyhow::Result<()> {
    use mxstab::coordinator::Sweeper;
    use mxstab::formats::spec::Fmt;
    use mxstab::runtime::{list_bundles, Session, StepArgs};

    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("index.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let session = Session::cpu()?;
    let sweeper = Sweeper::new(session, &artifacts);

    let schemes = [
        ("fp32", Fmt::fp32()),
        ("e4m3-full", Fmt::full(FormatId::E4M3, FormatId::E4M3)),
        ("e4m3-bf16act", Fmt::bf16_act(FormatId::E4M3)),
        ("e4m3-fwdonly", Fmt::fwd_only(FormatId::E4M3, FormatId::E4M3)),
    ];

    println!("== training-step throughput ==\n");
    let mut names = list_bundles(&artifacts)?;
    names.retain(|n| n != "quantizer" && !n.contains("pallas"));
    names.sort();
    for name in names {
        let runner = match sweeper.runner(&name) {
            Ok(r) => r,
            Err(e) => {
                println!("{name}: load failed: {e:#}");
                continue;
            }
        };
        let bundle = &runner.bundle;
        let n_params = bundle.manifest.n_params as f64;
        let tokens = bundle.tokens_shape();
        for (label, fmt) in &schemes {
            let mut state = Some(bundle.init(0, 0.0, 1.0)?);
            let mut step = 0i32;
            let corpus = runner.corpus.clone();
            let r = b.run(&format!("{name}/{label}"), || {
                let toks = match (&corpus, tokens) {
                    (Some(c), Some((bt, l))) => Some(c.batch(0, step as u64, bt, l)),
                    _ => None,
                };
                let args = StepArgs {
                    tokens: toks,
                    fmt: fmt.to_vec(),
                    hyper: vec![5e-4, 0.0, 0.0, 1e-3],
                    seed: 0,
                    step,
                };
                let (s2, m) = bundle.step(state.take().unwrap(), &args).unwrap();
                std::hint::black_box(m);
                state = Some(s2);
                step += 1;
            });
            // 6·N FLOPs per token-equivalent unit: use manifest FLOPs when
            // present (LM), else 6·N·batch for the proxy.
            let flops = bundle.manifest.flops_per_step.map(|f| f as f64).unwrap_or_else(|| {
                let batch = bundle.manifest.cfg_num("batch").unwrap_or(1.0);
                6.0 * n_params * batch
            });
            println!(
                "{}",
                r.report_line(&format!(
                    "{:.1} steps/s  {:.2} GFLOP/s(emu)",
                    1.0 / r.mean_s,
                    flops / r.mean_s / 1e9
                ))
            );
        }
        println!();
    }
    Ok(())
}
