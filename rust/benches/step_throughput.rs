//! L3 bench: end-to-end training-step throughput.
//!
//! Three faces:
//! * Always available — the pure-rust emulated **forward** GEMM over the
//!   packed MX engine: per-layer `C = A·Bᵀ` block GEMMs at the paper's
//!   proxy/LM shapes.
//! * Always available — the **backward** hot path: the transposed/backward
//!   GEMM variants (`dW = Xᵀ·G` re-blocked along the batch axis, mixed
//!   E4M3×E5M2 operands) and the **full native training step** (fwd +
//!   bwd + Adam + metrics) at the proxy shape — steps/s and emulated
//!   GFLOP/s for the path every native sweep rides.
//! * With `--features xla` + artifacts — compiled-bundle step throughput
//!   per precision scheme.

use mxstab::bench::Bencher;
use mxstab::formats::gemm::{gemm, PackedMatrix};
use mxstab::formats::spec::FormatId;
use mxstab::util::rng::Xoshiro256;

fn main() -> anyhow::Result<()> {
    let mut b = Bencher::default();
    b.warmup = 2;

    println!("== packed MX GEMM throughput (pure rust, no artifacts) ==\n");
    let mut rng = Xoshiro256::seed_from(0);
    // (m, n, k): proxy-MLP layer, LM attention-ish block, LM FFN.
    for &(m, n, k) in &[(128usize, 128usize, 512usize), (256, 256, 1024), (512, 2048, 512)] {
        let a = rng.normal_vec(m * k);
        let w = rng.normal_vec(n * k);
        let flops = (2 * m * n * k) as f64;
        for id in [FormatId::E4M3, FormatId::E5M2] {
            // Steady-state shape: weights stay packed across steps,
            // activations are re-encoded every call (as a step would).
            let wm = PackedMatrix::encode(&w, n, k, id, false);
            let mut c = vec![0.0f32; m * n];
            let r = b.run(&format!("gemm/{}/{}x{}x{}", id.name(), m, n, k), || {
                let am = PackedMatrix::encode(std::hint::black_box(&a), m, k, id, false);
                gemm(&am, &wm, &mut c);
                std::hint::black_box(&c);
            });
            println!(
                "{}",
                r.report_line(&format!("{:.2} GFLOP/s(emu)", flops / r.mean_s / 1e9))
            );
        }
    }
    println!();

    bench_backward_gemm(&b)?;
    bench_native_step(&b)?;
    bench_native_lm_step(&b)?;

    #[cfg(feature = "xla")]
    bench_bundles(&b)?;
    #[cfg(not(feature = "xla"))]
    println!("(built without `xla` — skipping compiled-bundle step benches)");
    Ok(())
}

/// The backward-GEMM hot path: weight gradients re-block both operands
/// along the batch axis (transposed encode), and the paper's MX-mix runs
/// E4M3 activations against E5M2 gradients in one GEMM.
fn bench_backward_gemm(b: &Bencher) -> anyhow::Result<()> {
    println!("== backward GEMM (transposed re-encode + mixed formats) ==\n");
    let mut rng = Xoshiro256::seed_from(1);
    // dW = Xᵀ·G at the proxy shape: batch 256, D 256, H 1024.
    let (batch, d, h) = (256usize, 256usize, 1024usize);
    let x = rng.normal_vec(batch * d);
    let g = rng.normal_vec(batch * h);
    let flops = (2 * d * h * batch) as f64;
    for (label, xa_id, g_id) in [
        ("e4m3xe4m3", FormatId::E4M3, FormatId::E4M3),
        ("e4m3xe5m2", FormatId::E4M3, FormatId::E5M2),
    ] {
        let mut dw = vec![0.0f32; d * h];
        let r = b.run(&format!("dw-gemm/{label}/{d}x{h}x{batch}"), || {
            // Both operands re-encode per call with blocks along the batch
            // axis — exactly what the native backward does every step.
            let xt = PackedMatrix::encode_t(std::hint::black_box(&x), batch, d, xa_id, false);
            let gt = PackedMatrix::encode_t(std::hint::black_box(&g), batch, h, g_id, false);
            gemm(&xt, &gt, &mut dw);
            std::hint::black_box(&dw);
        });
        println!("{}", r.report_line(&format!("{:.2} GFLOP/s(emu)", flops / r.mean_s / 1e9)));
    }
    println!();
    Ok(())
}

/// Full native training step (teacher fwd + student fwd + bwd + Adam +
/// metrics) at the proxy anchor shape, per precision scheme.
fn bench_native_step(b: &Bencher) -> anyhow::Result<()> {
    use mxstab::formats::spec::Fmt;
    use mxstab::runtime::native::NativeEngine;
    use mxstab::runtime::{Backend, Engine, StepArgs};

    println!("== native training-step throughput (pure rust) ==\n");
    let engine = NativeEngine::with_batch(256)?;
    let model = engine.load("proxy_gelu_ln_L4_D256")?;
    let n_params = model.n_params() as f64;
    let schemes = [
        ("fp32", Fmt::fp32()),
        ("e4m3-full", Fmt::full(FormatId::E4M3, FormatId::E4M3)),
        ("e4m3-bf16act", Fmt::bf16_act(FormatId::E4M3)),
        ("e4m3-fwdonly", Fmt::fwd_only(FormatId::E4M3, FormatId::E4M3)),
    ];
    for (label, fmt) in &schemes {
        let mut state = Some(model.init(0, 0.0, 1.0)?);
        let mut step = 0i32;
        let r = b.run(&format!("native/{}/{label}", model.name()), || {
            let args = StepArgs {
                tokens: None,
                fmt: fmt.to_vec(),
                hyper: vec![5e-4, 0.0, 0.0, 1e-3],
                seed: 0,
                step,
            };
            let (s2, m) = model.step(state.take().unwrap(), &args).unwrap();
            std::hint::black_box(m);
            state = Some(s2);
            step += 1;
        });
        // 6·N·batch FLOPs per step (fwd + bwd over N params, batch rows).
        let flops = 6.0 * n_params * 256.0;
        println!(
            "{}",
            r.report_line(&format!(
                "{:.1} steps/s  {:.2} GFLOP/s(emu)",
                1.0 / r.mean_s,
                flops / r.mean_s / 1e9
            ))
        );
    }
    println!();
    Ok(())
}

/// Full native transformer-LM training step (corpus batch + fwd + bwd +
/// Adam + metrics) at the smallest ladder rung, per precision scheme.
fn bench_native_lm_step(b: &Bencher) -> anyhow::Result<()> {
    use mxstab::coordinator::Sweeper;
    use mxstab::formats::spec::Fmt;
    use mxstab::runtime::native::NativeEngine;
    use mxstab::runtime::{Backend, StepArgs};

    println!("== native LM training-step throughput (pure rust) ==\n");
    let sweeper = Sweeper::new(NativeEngine::new());
    let runner = sweeper.runner("lm_olmo_1m")?;
    let model = runner.backend.clone();
    let corpus = runner.corpus.clone().expect("LM corpus");
    let n_params = model.n_params() as f64;
    let (batch, len) = model.tokens_shape().expect("LM tokens shape");
    let tokens_per_step = (batch * (len - 1)) as f64;
    let schemes = [
        ("fp32", Fmt::fp32()),
        ("e4m3-full", Fmt::full(FormatId::E4M3, FormatId::E4M3)),
        ("e4m3-bf16act", Fmt::bf16_act(FormatId::E4M3)),
    ];
    for (label, fmt) in &schemes {
        let mut state = Some(model.init(0, 0.0, 1.0)?);
        let mut step = 0i32;
        let r = b.run(&format!("native/{}/{label}", model.name()), || {
            let args = StepArgs {
                tokens: Some(corpus.batch(0, step as u64, batch, len)),
                fmt: fmt.to_vec(),
                hyper: vec![5e-4, 0.0, 0.0, 0.0],
                seed: 0,
                step,
            };
            let (s2, m) = model.step(state.take().unwrap(), &args).unwrap();
            std::hint::black_box(m);
            state = Some(s2);
            step += 1;
        });
        // 6·N FLOPs per token (fwd + bwd over N params).
        let flops = 6.0 * n_params * tokens_per_step;
        println!(
            "{}",
            r.report_line(&format!(
                "{:.2} steps/s  {:.0} tok/s  {:.2} GFLOP/s(emu)",
                1.0 / r.mean_s,
                tokens_per_step / r.mean_s,
                flops / r.mean_s / 1e9
            ))
        );
    }
    println!();
    Ok(())
}

#[cfg(feature = "xla")]
fn bench_bundles(b: &Bencher) -> anyhow::Result<()> {
    use mxstab::coordinator::Sweeper;
    use mxstab::formats::spec::Fmt;
    use mxstab::runtime::{list_bundles, PjrtEngine, Session, StepArgs};

    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("index.json").exists() {
        println!("artifacts missing — run `make artifacts` first");
        return Ok(());
    }
    let session = Session::cpu()?;
    let sweeper = Sweeper::new(PjrtEngine::new(session, &artifacts));

    let schemes = [
        ("fp32", Fmt::fp32()),
        ("e4m3-full", Fmt::full(FormatId::E4M3, FormatId::E4M3)),
        ("e4m3-bf16act", Fmt::bf16_act(FormatId::E4M3)),
        ("e4m3-fwdonly", Fmt::fwd_only(FormatId::E4M3, FormatId::E4M3)),
    ];

    println!("== training-step throughput (PJRT bundles) ==\n");
    let mut names = list_bundles(&artifacts)?;
    names.retain(|n| n != "quantizer" && !n.contains("pallas"));
    names.sort();
    for name in names {
        let runner = match sweeper.runner(&name) {
            Ok(r) => r,
            Err(e) => {
                println!("{name}: load failed: {e:#}");
                continue;
            }
        };
        let bundle = &runner.backend;
        let n_params = bundle.manifest.n_params as f64;
        let tokens = bundle.tokens_shape();
        for (label, fmt) in &schemes {
            let mut state = Some(bundle.init(0, 0.0, 1.0)?);
            let mut step = 0i32;
            let corpus = runner.corpus.clone();
            let r = b.run(&format!("{name}/{label}"), || {
                let toks = match (&corpus, tokens) {
                    (Some(c), Some((bt, l))) => Some(c.batch(0, step as u64, bt, l)),
                    _ => None,
                };
                let args = StepArgs {
                    tokens: toks,
                    fmt: fmt.to_vec(),
                    hyper: vec![5e-4, 0.0, 0.0, 1e-3],
                    seed: 0,
                    step,
                };
                let (s2, m) = bundle.step(state.take().unwrap(), &args).unwrap();
                std::hint::black_box(m);
                state = Some(s2);
                step += 1;
            });
            // 6·N FLOPs per token-equivalent unit: use manifest FLOPs when
            // present (LM), else 6·N·batch for the proxy.
            let flops = bundle.manifest.flops_per_step.map(|f| f as f64).unwrap_or_else(|| {
                let batch = bundle.manifest.cfg_num("batch").unwrap_or(1.0);
                6.0 * n_params * batch
            });
            println!(
                "{}",
                r.report_line(&format!(
                    "{:.1} steps/s  {:.2} GFLOP/s(emu)",
                    1.0 / r.mean_s,
                    flops / r.mean_s / 1e9
                ))
            );
        }
        println!();
    }
    Ok(())
}
