//! End-to-end bench: times a miniature slice of every paper-figure
//! pipeline (sweep → detect → analyze → render), one bench per
//! table/figure family. This is the `cargo bench` face of the experiment
//! harness — the full-scale regeneration lives in `mxstab experiment <id>`.
//!
//! The analytics slices are pure rust and always run; the training-backed
//! slices need `--features xla` plus compiled artifacts.

use std::time::Instant;

use mxstab::analysis::spikes::count_spikes;
use mxstab::analysis::{fit_chinchilla, LossPoint};
use mxstab::formats::codes;
use mxstab::formats::spec::FormatId;
use mxstab::util::rng::Xoshiro256;

fn timed(name: &str, f: impl FnOnce() -> anyhow::Result<String>) {
    let t0 = Instant::now();
    match f() {
        Ok(extra) => println!("{name:<34} {:>8.2}s   {extra}", t0.elapsed().as_secs_f64()),
        Err(e) => println!("{name:<34} FAILED: {e:#}"),
    }
}

fn main() -> anyhow::Result<()> {
    println!("== per-figure pipeline benches (miniature slices) ==\n");

    // Fig. 5 left / format tables — pure rust, no artifacts needed.
    timed("fig5-left: code tables", || {
        let mut total = 0usize;
        for id in [FormatId::E4M3, FormatId::E5M2, FormatId::E2M3, FormatId::E3M2] {
            total += codes::relative_gaps(&id.elem().unwrap()).len();
        }
        Ok(format!("{total} code gaps enumerated"))
    });

    // Table 2 analytics: Chinchilla fit on synthetic points.
    timed("tab2: chinchilla fit (24 pts)", || {
        let mut rng = Xoshiro256::seed_from(3);
        let mut pts = vec![];
        for &n in &[1e5, 1e6, 1e7] {
            for &r in &[2.0, 8.0, 32.0, 128.0] {
                pts.push(LossPoint {
                    n_params: n,
                    tokens: n * r,
                    loss: 0.5 + 2e3 / n.powf(0.5) + 2e4 / (n * r).powf(0.55)
                        + 0.001 * rng.normal().abs(),
                });
            }
        }
        let fit = fit_chinchilla(&pts);
        Ok(format!("alpha={:.3} beta={:.3}", fit.alpha, fit.beta))
    });

    // Fig. 9 analytics: spike counting over a synthetic 10k-step series.
    timed("fig9: spike census (100 series)", || {
        let mut rng = Xoshiro256::seed_from(4);
        let mut total = 0;
        for _ in 0..100 {
            let mut loss = 1.0;
            let series: Vec<f64> = (0..10_000)
                .map(|_| {
                    loss *= 1.0 - 0.0001 + 0.001 * rng.normal();
                    if rng.next_f64() < 0.0005 {
                        loss * 500.0
                    } else {
                        loss
                    }
                })
                .collect();
            total += count_spikes(&series, 100.0);
        }
        Ok(format!("{total} spikes"))
    });

    #[cfg(feature = "xla")]
    training_benches()?;
    #[cfg(not(feature = "xla"))]
    println!("\n(built without `xla` — skipping training-pipeline benches)");
    Ok(())
}

#[cfg(feature = "xla")]
fn training_benches() -> anyhow::Result<()> {
    use mxstab::coordinator::{Intervention, Job, RunConfig, Sweeper};
    use mxstab::formats::spec::Fmt;
    use mxstab::runtime::{list_bundles, PjrtEngine, Session};

    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("index.json").exists() {
        println!("\n(artifacts missing — skipping training-pipeline benches)");
        return Ok(());
    }
    let session = Session::cpu()?;
    let sweeper = Sweeper::new(PjrtEngine::new(session, &artifacts));
    let proxy = list_bundles(&artifacts)?
        .into_iter()
        .find(|n| n.starts_with("proxy_gelu_ln"))
        .expect("proxy bundle");

    // Fig. 1/2/3-style mini-sweep: 2 formats × 20 steps.
    timed("fig1/2/3: mini sweep (2×20 steps)", || {
        let jobs: Vec<Job> =
            [("fp32", Fmt::fp32()), ("e4m3", Fmt::full(FormatId::E4M3, FormatId::E4M3))]
                .into_iter()
                .map(|(l, f)| Job { bundle: proxy.clone(), cfg: RunConfig::new(l, f, 5e-4, 20) })
                .collect();
        let logs = sweeper.run_all(&jobs, true);
        Ok(format!(
            "final losses: {:?}",
            logs.iter().map(|l| l.final_loss()).collect::<Vec<_>>()
        ))
    });

    // Fig. 7-style: snapshot + one intervention branch.
    timed("fig7: snapshot + branch (30 steps)", || {
        let runner = sweeper.runner(&proxy)?;
        let cfg = RunConfig::new("b", Fmt::full(FormatId::E4M3, FormatId::E4M3), 1e-3, 30);
        let (_base, snap) = runner.run_with_snapshot(&cfg, 15)?;
        let cfg2 = RunConfig::new("iv", Intervention::Bf16Act.apply(cfg.fmt), 1e-3, 30);
        let out = runner.run_from(&cfg2, snap, 15)?;
        Ok(format!("branch final {:.4}", out.log.final_loss()))
    });

    // Fig. 4-style: paired-gradient steps.
    timed("fig4: paired steps (10)", || {
        let paired = list_bundles(&artifacts)?
            .into_iter()
            .filter(|n| n.starts_with("proxy"))
            .find(|n| {
                mxstab::runtime::Manifest::load(&artifacts.join(n))
                    .map(|m| m.functions.contains_key("paired"))
                    .unwrap_or(false)
            });
        let Some(name) = paired else { return Ok("no paired bundle".into()) };
        let runner = sweeper.runner(&name)?;
        let mut cfg = RunConfig::new("p", Fmt::full(FormatId::E4M3, FormatId::E4M3), 5e-4, 10);
        cfg.paired = true;
        let out = runner.run(&cfg)?;
        Ok(format!("eps_ratio@end {:.4}", out.log.rows.last().unwrap().m.eps_ratio))
    });

    Ok(())
}
