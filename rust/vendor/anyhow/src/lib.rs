//! Offline vendored shim of the `anyhow` error API.
//!
//! The build environment has no crates.io access, so this workspace member
//! provides the (small) subset of `anyhow` that mxstab uses, under the same
//! crate name and paths: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros. Swapping this
//! path dependency for the real `anyhow = "1"` is a one-line change in
//! `rust/Cargo.toml`; no source edits are required.
//!
//! Semantics intentionally mirrored from upstream:
//! * `?` converts any `E: std::error::Error + Send + Sync + 'static` into
//!   [`Error`], capturing the `source()` chain.
//! * `Display` prints the outermost message; alternate `{:#}` prints the
//!   whole chain joined by `": "`.
//! * `Debug` (what `fn main() -> Result<()>` prints) shows the message plus
//!   a `Caused by:` list.

use std::error::Error as StdError;
use std::fmt;

/// Error type: an outermost message plus an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message (the `anyhow!` macro).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an additional layer of context.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The error chain, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = &e.source;
        }
        out
    }

    /// The innermost error message.
    pub fn root_cause(&self) -> &str {
        *self.chain().last().unwrap()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain().join(": "))
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, exactly like
// upstream anyhow: that keeps this blanket `From` coherent and makes `?`
// work on any std-error result.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.unwrap()
    }
}

/// `anyhow::Result<T>` — alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e).context("reading config")
    }

    #[test]
    fn chain_and_alternate_display() {
        let e = fails_io().unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert_eq!(e.root_cause(), "gone");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"));
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative: -2");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("nope").is_err());
    }
}
