//! Offline stub of the PJRT/XLA binding used by mxstab's `runtime` layer.
//!
//! The real backend is an `xla-rs`-style binding over `libxla_extension`
//! (PJRT C API). That shared library is multi-GB and unavailable in the
//! offline build image, so this workspace member mirrors the *exact* API
//! surface `mxstab::runtime` consumes:
//!
//! * [`Literal`] — a fully functional host-side tensor container
//!   (f32/i32, shape, reshape, typed extraction).
//! * [`PjRtClient`] / [`PjRtBuffer`] / [`PjRtLoadedExecutable`] /
//!   [`HloModuleProto`] / [`XlaComputation`] — type- and
//!   signature-compatible stubs whose device entry points return
//!   [`Error::Unavailable`] at runtime.
//!
//! `PjRtClient::cpu()` fails first, so the device-side methods are
//! unreachable in practice; they exist so `cargo build --features xla`
//! type-checks everywhere (benches, examples, integration tests) without
//! the native library. Deploying for real means replacing this path
//! dependency in `rust/Cargo.toml` with the actual binding — no source
//! changes in mxstab (see DESIGN.md §6).

use std::fmt;

/// Stub error: every device operation reports the backend as unavailable.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
    Shape(String),
    Type(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT backend unavailable (built against the offline `xla` stub; \
                 swap rust/vendor/xla for a real binding to run compiled bundles)"
            ),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Type(m) => write!(f, "type error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold.
#[derive(Debug, Clone)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }
}

/// Types storable in a [`Literal`] (mirror of the binding's `NativeType`).
pub trait NativeType: Copy {
    fn wrap(v: Vec<Self>) -> Data;
    fn extract(d: &Data) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn extract(d: &Data) -> Result<Vec<Self>> {
        match d {
            Data::F32(v) => Ok(v.clone()),
            Data::I32(_) => Err(Error::Type("literal holds i32, requested f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn extract(d: &Data) -> Result<Vec<Self>> {
        match d {
            Data::I32(v) => Ok(v.clone()),
            Data::F32(_) => Err(Error::Type("literal holds f32, requested i32".into())),
        }
    }
}

/// Host-side tensor value. Fully functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::wrap(data.to_vec()) }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], data: T::wrap(vec![v]) }
    }

    /// Reshape without moving data; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::Shape(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Extract as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.data)
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }
}

/// Parsed HLO module (stub: construction always fails).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle (stub: `cpu()` always fails, so downstream device
/// methods are unreachable but type-check).
#[derive(Debug, Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("buffer_from_host_literal"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

/// Device buffer handle (stub).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }

    pub fn client(&self) -> PjRtClient {
        PjRtClient(())
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with owned literal inputs.
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }

    /// Execute with device-buffer inputs.
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _inputs: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(m.to_vec::<i32>().is_err());
        let s = Literal::scalar(7i32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn device_paths_report_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("unavailable"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
