//! The shipped tree must be clean under `mxstab analyze --strict`: zero
//! violations and zero unused allows. This is the same invariant CI's
//! `analyze` job enforces via the binary; running it as a cargo test
//! keeps `cargo test` self-contained on a bare machine.

use std::path::Path;

use mxstab::analyze::{analyze_paths, default_roots, Options};

#[test]
fn shipped_tree_is_clean_under_strict_analyze() {
    // CARGO_MANIFEST_DIR is rust/, so default_roots resolves src/,
    // tests/, benches/ directly.
    let roots = default_roots(Path::new(env!("CARGO_MANIFEST_DIR")));
    assert!(!roots.is_empty(), "no source roots found");
    let report =
        analyze_paths(&roots, &Options::default()).expect("walking the source tree");
    let rendered: Vec<String> = report
        .violations
        .iter()
        .chain(report.unused_allows.iter())
        .map(|d| d.render())
        .collect();
    assert!(
        report.violations.is_empty() && report.unused_allows.is_empty(),
        "analyze must be clean on the shipped tree:\n{}",
        rendered.join("\n")
    );
    assert!(
        report.files_scanned >= 60,
        "suspiciously few files scanned ({}) — did the walker break?",
        report.files_scanned
    );
}

#[test]
fn mmap_boundary_is_clean_under_confinement() {
    // util/mmap.rs is the one sanctioned unsafe file outside the kernel
    // ISA modules: every unsafe block there must carry its SAFETY
    // comment, and the sanctioning must make the file scan clean without
    // any allow pragma. Analyzing it in isolation (default scoped
    // options, same as the tree pass) pins that down.
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/util/mmap.rs");
    let source = std::fs::read_to_string(&path).expect("reading src/util/mmap.rs");
    assert!(source.contains("unsafe"), "mmap.rs lost its unsafe boundary?");
    let out = mxstab::analyze::analyze_source(
        "rust/src/util/mmap.rs",
        &source,
        &Options::default(),
    );
    assert!(
        out.violations.is_empty(),
        "util/mmap.rs must scan clean as a sanctioned boundary:\n{}",
        out.violations.iter().map(|d| d.render()).collect::<Vec<_>>().join("\n")
    );
}
