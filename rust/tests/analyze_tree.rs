//! The shipped tree must be clean under `mxstab analyze --strict`: zero
//! violations and zero unused allows. This is the same invariant CI's
//! `analyze` job enforces via the binary; running it as a cargo test
//! keeps `cargo test` self-contained on a bare machine.

use std::path::Path;

use mxstab::analyze::{analyze_paths, default_roots, Options};

#[test]
fn shipped_tree_is_clean_under_strict_analyze() {
    // CARGO_MANIFEST_DIR is rust/, so default_roots resolves src/,
    // tests/, benches/ directly.
    let roots = default_roots(Path::new(env!("CARGO_MANIFEST_DIR")));
    assert!(!roots.is_empty(), "no source roots found");
    let report =
        analyze_paths(&roots, &Options::default()).expect("walking the source tree");
    let rendered: Vec<String> = report
        .violations
        .iter()
        .chain(report.unused_allows.iter())
        .map(|d| d.render())
        .collect();
    assert!(
        report.violations.is_empty() && report.unused_allows.is_empty(),
        "analyze must be clean on the shipped tree:\n{}",
        rendered.join("\n")
    );
    assert!(
        report.files_scanned >= 60,
        "suspiciously few files scanned ({}) — did the walker break?",
        report.files_scanned
    );
}
