//! Sub-byte packed storage + generalized block geometry (DESIGN.md
//! §Formats, "Storage layout").
//!
//! Proves the PR-level contract of the nibble-packed codec: for every
//! (element format × block size × scaling mode) combination the packed
//! path is **bitwise identical** to the scalar `mx_qdq_geom` /
//! `mx_dot_geom` oracles — on adversarial inputs (subnormal amax, zero
//! blocks, NaN/Inf, non-multiple-of-block tails) — and a multi-step
//! fully-quantized FP4 LM training trajectory is bitwise independent of
//! whether 4-bit codes are stored packed (two per byte) or expanded to
//! one byte each.
//!
//! [`set_unpacked_subbyte_storage`] is process-global, so tests that flip
//! it (or assert storage density, which depends on it) serialize on one
//! mutex and restore the default on entry.

use std::sync::{Mutex, MutexGuard};

use mxstab::data::{Corpus, CorpusConfig};
use mxstab::formats::dot::mx_dot_geom;
use mxstab::formats::gemm::{gemm, PackedMatrix};
use mxstab::formats::packed::{packed_qdq_geom, set_unpacked_subbyte_storage, PackedVec};
use mxstab::formats::quant::mx_qdq_geom;
use mxstab::formats::spec::{hyper_idx, BlockGeom, Fmt, FormatId, BLOCK_SIZES};
use mxstab::runtime::native::{LmConfig, LmModel, NativeState};
use mxstab::runtime::{Backend, Metrics, StepArgs};
use mxstab::util::rng::Xoshiro256;

static STORAGE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    let g = STORAGE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_unpacked_subbyte_storage(false); // restore the packed default
    g
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every geometry the runtime accepts.
fn geometries() -> Vec<BlockGeom> {
    let mut v = Vec::new();
    for &bs in &BLOCK_SIZES {
        for two_level in [false, true] {
            v.push(BlockGeom::new(bs, two_level));
        }
    }
    v
}

/// Adversarial input of `len` elements: normals, wide dynamic range, f32
/// subnormals, ±0, ±inf, NaN, the §6.1 clamp cluster — plus one
/// guaranteed all-zero block and one all-subnormal (subnormal-amax) block.
fn adversarial(rng: &mut Xoshiro256, len: usize, block_size: usize) -> Vec<f32> {
    let mut x = Vec::with_capacity(len);
    for i in 0..len {
        x.push(match i % 10 {
            0 => rng.normal() as f32,
            1 => (rng.normal() as f32) * (2.0f32).powi((rng.below(60) as i32) - 30),
            2 => f32::from_bits(rng.below(1 << 23) as u32), // subnormal
            3 => 0.0,
            4 => -0.0,
            5 => f32::INFINITY,
            6 => f32::NEG_INFINITY,
            7 => f32::NAN,
            8 => 0.897, // clamp cluster
            _ => rng.normal() as f32 * 0.01,
        });
    }
    for v in x.iter_mut().take(block_size.min(len)) {
        *v = 0.0;
    }
    if len >= 2 * block_size {
        for v in x.iter_mut().skip(block_size).take(block_size) {
            *v = f32::from_bits(1 + rng.below(100) as u32); // subnormal amax
        }
    }
    x
}

const SUBBYTE: [FormatId; 2] = [FormatId::E2M1, FormatId::Int4];

#[test]
fn qdq_bitwise_parity_for_every_format_geometry_and_tail() {
    let _g = lock();
    let mut rng = Xoshiro256::seed_from(7);
    for geom in geometries() {
        // Block-aligned and ragged-tail lengths (tails are legal in the
        // flat codec; the last block is simply shorter).
        for len in [4 * geom.block_size, 4 * geom.block_size + 7, geom.block_size - 1] {
            let x = adversarial(&mut rng, len, geom.block_size);
            for id in FormatId::ALL {
                let (want, cw) = mx_qdq_geom(&x, id, false, geom);
                let (got, cg) = packed_qdq_geom(&x, id, false, geom);
                assert_eq!(
                    bits(&want),
                    bits(&got),
                    "{id:?} {geom:?} len {len}: packed qdq diverged from oracle"
                );
                assert_eq!(cw, cg, "{id:?} {geom:?} len {len}: clamp count");
                // Scale-bump variant too.
                let (want_b, _) = mx_qdq_geom(&x, id, true, geom);
                let (got_b, _) = packed_qdq_geom(&x, id, true, geom);
                assert_eq!(bits(&want_b), bits(&got_b), "{id:?} {geom:?} len {len}: bump");
            }
        }
    }
}

#[test]
fn nibble_storage_is_dense_and_roundtrips() {
    let _g = lock();
    let mut rng = Xoshiro256::seed_from(11);
    for geom in geometries() {
        for len in [6 * geom.block_size, 6 * geom.block_size + 13] {
            let x = adversarial(&mut rng, len, geom.block_size);
            for id in SUBBYTE {
                let p = PackedVec::encode_geom(&x, id, false, geom);
                assert!(p.packed4(), "{id:?} must pack two codes per byte by default");
                assert_eq!(p.codes.len(), len.div_ceil(2), "{id:?} {geom:?} len {len}");
                // Effective storage: 0.5 B/elem of codes plus scale
                // overhead. Block 16 pays the most per-block scale (2-byte
                // one-level scales: 0.625 exactly; two-level at these short
                // lengths: ~0.605, the f32 tensor scale barely amortized);
                // blocks 32/64 stay under 0.6.
                let bpe = p.bytes() as f64 / len as f64;
                let bar = if geom.block_size == 16 { 0.65 } else { 0.6 };
                assert!(bpe <= bar, "{id:?} {geom:?} len {len}: {bpe} bytes/elem > {bar}");
                // Decode equals the oracle qdq values.
                let mut dec = vec![0.0f32; len];
                p.decode_into(&mut dec);
                let (want, _) = mx_qdq_geom(&x, id, false, geom);
                assert_eq!(bits(&want), bits(&dec), "{id:?} {geom:?} len {len}: decode");
            }
        }
    }
}

#[test]
fn byte_expanded_storage_is_bitwise_equal_to_nibble_packed() {
    let _g = lock();
    let mut rng = Xoshiro256::seed_from(13);
    for geom in geometries() {
        let len = 5 * geom.block_size;
        let x = adversarial(&mut rng, len, geom.block_size);
        for id in SUBBYTE {
            let (nib, _) = packed_qdq_geom(&x, id, false, geom);
            set_unpacked_subbyte_storage(true);
            let p = PackedVec::encode_geom(&x, id, false, geom);
            let (byte, _) = packed_qdq_geom(&x, id, false, geom);
            set_unpacked_subbyte_storage(false);
            assert!(!p.packed4(), "toggle must force byte storage");
            assert_eq!(bits(&nib), bits(&byte), "{id:?} {geom:?}: storage changed values");
        }
    }
}

#[test]
fn subbyte_gemm_matches_geom_dot_oracle() {
    let _g = lock();
    // Single-row operands so the two-level per-tensor scale of the matrix
    // equals the per-slice scale the self-contained oracle derives.
    let mut rng = Xoshiro256::seed_from(17);
    for geom in geometries() {
        let k = 4 * geom.block_size;
        let a: Vec<f32> = rng.normal_vec(k);
        let b: Vec<f32> = rng.normal_vec(k);
        for id in SUBBYTE {
            let am = PackedMatrix::encode_geom(&a, 1, k, id, false, geom);
            let bm = PackedMatrix::encode_geom(&b, 1, k, id, false, geom);
            let mut c = [0.0f32];
            gemm(&am, &bm, &mut c);
            let want = mx_dot_geom(&a, &b, id, false, geom);
            assert_eq!(
                c[0].to_bits(),
                want.to_bits(),
                "{id:?} {geom:?}: gemm {} vs oracle {want}",
                c[0]
            );
        }
    }
}

// ---------------------------------------------------------------------------
// End-to-end: FP4 LM training trajectories.
// ---------------------------------------------------------------------------

fn tiny_lm() -> LmModel {
    LmModel::new(LmConfig { layers: 2, d_model: 32, n_heads: 1, vocab: 64, ctx: 32, batch: 2 })
        .unwrap()
}

fn lm_args(m: &LmModel, corpus: &Corpus, fmt: Fmt, step: i32) -> StepArgs {
    let (b, l) = m.tokens_shape().unwrap();
    let mut hyper = vec![0.0f32; hyper_idx::HYPER_LEN];
    hyper[hyper_idx::LR] = 2e-3;
    let tokens = Some(corpus.batch(9, step as u64, b, l));
    StepArgs { tokens, fmt: fmt.to_vec(), hyper, seed: 9, step }
}

fn metric_bits(m: &Metrics) -> [u32; 4] {
    [
        m.loss.to_bits(),
        m.grad_norm.to_bits(),
        m.update_norm.to_bits(),
        m.param_norm.to_bits(),
    ]
}

/// Run `steps` fully-quantized LM steps under `fmt` and return per-step
/// metric bits plus the final state snapshot.
fn lm_trajectory(
    m: &LmModel,
    corpus: &Corpus,
    fmt: Fmt,
    steps: i32,
) -> (Vec<[u32; 4]>, Vec<Vec<f32>>) {
    let mut state: NativeState = m.init(5, 0.0, 1.0).unwrap();
    let mut mets = Vec::new();
    for step in 0..steps {
        let args = lm_args(m, corpus, fmt, step);
        let (s2, met) = m.step(state, &args).unwrap();
        state = s2;
        mets.push(metric_bits(&met));
    }
    let snap = m.snapshot(&state).unwrap();
    (mets, snap)
}

#[test]
fn fp4_lm_trajectory_bitwise_equal_u8_vs_nibble_storage() {
    let _g = lock();
    let m = tiny_lm();
    let corpus = Corpus::new(CorpusConfig { vocab: m.config().vocab, ..Default::default() });
    let fmt = Fmt::full(FormatId::E2M1, FormatId::E2M1);
    let steps = 4;
    let (met_nib, snap_nib) = lm_trajectory(&m, &corpus, fmt, steps);
    set_unpacked_subbyte_storage(true);
    let (met_u8, snap_u8) = lm_trajectory(&m, &corpus, fmt, steps);
    set_unpacked_subbyte_storage(false);
    assert_eq!(met_nib, met_u8, "metrics diverged between nibble and byte storage");
    assert_eq!(snap_nib.len(), snap_u8.len());
    for (i, (a, b)) in snap_nib.iter().zip(&snap_u8).enumerate() {
        assert_eq!(bits(a), bits(b), "state tensor {i} diverged after {steps} steps");
    }
}

#[test]
fn lm_trains_under_fp4_two_level_small_block_geometry() {
    let _g = lock();
    // Smoke the full runtime threading of a non-default geometry: block
    // size 16 with NVFP4-style two-level scaling, FP4 everywhere.
    let m = tiny_lm();
    let corpus = Corpus::new(CorpusConfig { vocab: m.config().vocab, ..Default::default() });
    let fmt = Fmt::full(FormatId::E2M1, FormatId::E2M1).with_geom(BlockGeom::new(16, true));
    let (mets, snap) = lm_trajectory(&m, &corpus, fmt, 2);
    for (s, mb) in mets.iter().enumerate() {
        for (i, &b) in mb.iter().enumerate() {
            assert!(f32::from_bits(b).is_finite(), "step {s} metric {i} not finite");
        }
    }
    assert!(snap.iter().flatten().all(|v| v.is_finite()), "state blew up");
}
