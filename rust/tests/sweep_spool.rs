//! Adversarial integration tests for the spooled sweep coordinator:
//! lease races, injected worker kills, stale-lease reclaim, exactly-once
//! completion, and the headline invariant — a crash-resumed job's
//! `done/<id>.jsonl` is **bitwise identical** to an uninterrupted run.
//!
//! All tests share one process (cargo runs them on parallel threads), so
//! every test uses scope-unique worker ids / run names and clears its
//! faults on exit — the fault registry only fires on matching scopes.

use std::path::{Path, PathBuf};

use mxstab::coordinator::{run_worker, Job, RunConfig, RunLog, Spool, Sweeper, WorkerConfig};
use mxstab::formats::spec::{Fmt, FormatId};
use mxstab::runtime::NativeEngine;
use mxstab::util::faults::{self, Fault};

fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mxstab_spool_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Tiny quantized transformer-LM job — big enough to have real state
/// (embeddings, attention, Adam moments), small enough for seconds.
fn lm_job(name: &str, seed: i32, steps: usize) -> Job {
    let mut cfg = RunConfig::new(name, Fmt::full(FormatId::E4M3, FormatId::E4M3), 1e-3, steps);
    cfg.seed = seed;
    cfg.log_every = 1;
    Job { bundle: "lm_L1_D32_H1_T32_V64".into(), cfg }
}

fn sweeper() -> Sweeper<NativeEngine> {
    Sweeper::new(NativeEngine::with_batch(2).unwrap())
}

fn wcfg(id: &str, lease_timeout_ms: u64) -> WorkerConfig {
    let mut w = WorkerConfig::new(id);
    w.checkpoint_every = 10;
    w.lease_timeout_ms = lease_timeout_ms;
    w.poll_ms = 20;
    w
}

fn jsonl_count(dir: &Path, sub: &str) -> usize {
    std::fs::read_dir(dir.join(sub))
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().ends_with(".jsonl"))
                .count()
        })
        .unwrap_or(0)
}

#[test]
fn exactly_one_worker_wins_each_lease() {
    let dir = tdir("race");
    let spool = Spool::init(&dir).unwrap();
    for round in 0..8 {
        spool.enqueue(&lm_job(&format!("race_{round}"), 0, 1)).unwrap();
        let s = &spool;
        let wins: Vec<bool> = std::thread::scope(|sc| {
            let handles: Vec<_> = (0..2)
                .map(|w| {
                    sc.spawn(move || s.try_lease(&format!("race_w{w}")).unwrap().is_some())
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let winners = wins.iter().filter(|w| **w).count();
        assert_eq!(winners, 1, "round {round}: exactly one lease winner, got {winners}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The tentpole invariant: kill a worker mid-job, let a sibling reclaim
/// and resume from the checkpoint ring, and the final `done/` log must
/// be byte-identical to both (a) an uninterrupted spooled run and (b) a
/// plain single-process `Runner` run with no spool at all.
#[test]
fn killed_worker_resumes_bitwise_identical() {
    let dir_g = tdir("parity_gold");
    let dir_f = tdir("parity_fault");
    let jobs = [lm_job("parity_a", 1, 60), lm_job("parity_b", 2, 60)];
    let sw = sweeper();

    // Golden: uninterrupted single-worker spooled run.
    let golden = Spool::init(&dir_g).unwrap();
    for j in &jobs {
        golden.enqueue(j).unwrap();
    }
    let rep = run_worker(&sw, &golden, &wcfg("parity_gold_w", 60_000)).unwrap();
    assert_eq!(rep.completed.len(), 2);
    assert!(!rep.killed);

    // Reference: the spool machinery must not perturb the trajectory.
    let direct = sw.runner(&jobs[0].bundle).unwrap().run(&jobs[0].cfg).unwrap();
    assert_eq!(
        RunLog::rows_jsonl(&direct.log.rows).into_bytes(),
        std::fs::read(dir_g.join("done/parity_a.jsonl")).unwrap(),
        "spooled run must match a plain Runner run byte-for-byte"
    );

    // Faulted: two workers, one killed mid-job at step 35 (checkpoints
    // land every 10 steps, so the survivor resumes at 30 and recomputes
    // 30..35 before continuing).
    faults::arm(Fault::kill_worker("parity_kw0", 35));
    let faulted = Spool::init(&dir_f).unwrap();
    for j in &jobs {
        faulted.enqueue(j).unwrap();
    }
    std::thread::scope(|sc| {
        let (sw, faulted) = (&sw, &faulted);
        let h0 = sc.spawn(move || run_worker(sw, faulted, &wcfg("parity_kw0", 400)).unwrap());
        let h1 = sc.spawn(move || run_worker(sw, faulted, &wcfg("parity_kw1", 400)).unwrap());
        let (r0, r1) = (h0.join().unwrap(), h1.join().unwrap());
        assert!(r0.killed, "the scoped kill fault must hit worker parity_kw0");
        assert!(!r1.killed);
        assert!(!r1.reclaimed.is_empty(), "the survivor reclaims the dead worker's lease");
    });
    faults::clear_scope("parity_kw0");

    // Every job reached done/ exactly once, bitwise equal to golden.
    assert_eq!(jsonl_count(&dir_f, "done"), 2);
    assert_eq!(jsonl_count(&dir_f, "failed"), 0);
    for id in ["parity_a", "parity_b"] {
        assert_eq!(
            std::fs::read(dir_f.join(format!("done/{id}.jsonl"))).unwrap(),
            std::fs::read(dir_g.join(format!("done/{id}.jsonl"))).unwrap(),
            "{id}: resumed trajectory must be bitwise identical"
        );
    }
    std::fs::remove_dir_all(&dir_g).ok();
    std::fs::remove_dir_all(&dir_f).ok();
}

/// A worker with stalled heartbeats is killed mid-job; its lease (whose
/// heartbeat never advanced past the initial lease stamp) goes stale and
/// a live worker reclaims and finishes from the checkpoint ring.
#[test]
fn stalled_heartbeat_lease_is_reclaimed() {
    let dir = tdir("stall");
    let spool = Spool::init(&dir).unwrap();
    spool.enqueue(&lm_job("stall_a", 3, 20)).unwrap();
    let sw = sweeper();

    faults::arm(Fault::stall_heartbeat("stall_zw"));
    faults::arm(Fault::kill_worker("stall_zw", 15));
    let rep = run_worker(&sw, &spool, &wcfg("stall_zw", 60_000)).unwrap();
    assert!(rep.killed);
    faults::clear_scope("stall_zw");

    std::thread::sleep(std::time::Duration::from_millis(120));
    let rep = run_worker(&sw, &spool, &wcfg("stall_live", 100)).unwrap();
    assert_eq!(rep.reclaimed, vec!["stall_a".to_string()]);
    assert_eq!(rep.completed, vec!["stall_a".to_string()]);
    let winner = std::fs::read(dir.join("done/stall_a.jsonl")).unwrap();
    let direct = sw.runner("lm_L1_D32_H1_T32_V64").unwrap();
    let out = direct.run(&lm_job("stall_a", 3, 20).cfg).unwrap();
    assert_eq!(RunLog::rows_jsonl(&out.log.rows).into_bytes(), winner);
    std::fs::remove_dir_all(&dir).ok();
}

/// A zombie that wakes up *after* its job was reclaimed and completed
/// must lose the exactly-once commit and leave the winner's log intact.
#[test]
fn duplicate_completion_loses_exactly_once_commit() {
    let dir = tdir("dup_commit");
    let spool = Spool::init(&dir).unwrap();
    let job = lm_job("dupc_a", 4, 20);
    spool.enqueue(&job).unwrap();
    let sw = sweeper();

    // Zombie leases, then goes silent without ever heartbeating again.
    let zombie = spool.try_lease("dupc_zombie").unwrap().expect("lease");
    std::thread::sleep(std::time::Duration::from_millis(120));

    // A live worker reclaims the stale lease and finishes the job.
    let rep = run_worker(&sw, &spool, &wcfg("dupc_live", 100)).unwrap();
    assert_eq!(rep.reclaimed, vec!["dupc_a".to_string()]);
    assert_eq!(rep.completed, vec!["dupc_a".to_string()]);
    let winner = std::fs::read(dir.join("done/dupc_a.jsonl")).unwrap();

    // The zombie finishes anyway and tries to publish: it must lose.
    let out = sw.runner(&job.bundle).unwrap().run(&job.cfg).unwrap();
    assert!(!spool.complete(&zombie, &out.log).unwrap(), "duplicate completion must lose");
    assert_eq!(std::fs::read(dir.join("done/dupc_a.jsonl")).unwrap(), winner);
    assert_eq!(jsonl_count(&dir, "done"), 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// Killed before the first checkpoint: the reclaimer finds no usable
/// ring entry and restarts from scratch — still bitwise identical.
#[test]
fn reclaim_before_first_checkpoint_restarts_from_scratch() {
    let dir = tdir("fresh");
    let spool = Spool::init(&dir).unwrap();
    let job = lm_job("fresh_a", 5, 25);
    spool.enqueue(&job).unwrap();
    let sw = sweeper();

    faults::arm(Fault::kill_worker("fresh_kw", 3));
    let rep = run_worker(&sw, &spool, &wcfg("fresh_kw", 60_000)).unwrap();
    assert!(rep.killed);
    faults::clear_scope("fresh_kw");
    assert!(
        spool.checkpoints().latest("fresh_a").is_none(),
        "killed at step 3 with checkpoint_every=10: no checkpoint exists"
    );

    std::thread::sleep(std::time::Duration::from_millis(120));
    let rep = run_worker(&sw, &spool, &wcfg("fresh_live", 100)).unwrap();
    assert_eq!(rep.reclaimed, vec!["fresh_a".to_string()]);
    assert_eq!(rep.completed, vec!["fresh_a".to_string()]);
    let direct = sw.runner(&job.bundle).unwrap().run(&job.cfg).unwrap();
    assert_eq!(
        RunLog::rows_jsonl(&direct.log.rows).into_bytes(),
        std::fs::read(dir.join("done/fresh_a.jsonl")).unwrap()
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A corrupted pending-job file routes to failed/ with an error-marked
/// log while sibling jobs complete normally.
#[test]
fn corrupt_pending_job_fails_and_siblings_finish() {
    let dir = tdir("corrupt");
    let spool = Spool::init(&dir).unwrap();
    spool.enqueue(&lm_job("corrupt_ok", 7, 15)).unwrap();
    std::fs::write(dir.join("pending/corrupt_bad.json"), b"{ not json").unwrap();
    let sw = sweeper();

    let rep = run_worker(&sw, &spool, &wcfg("corrupt_w", 60_000)).unwrap();
    assert_eq!(rep.completed, vec!["corrupt_ok".to_string()]);
    assert_eq!(rep.failed, vec!["corrupt_bad".to_string()]);
    assert!(dir.join("done/corrupt_ok.jsonl").exists());
    assert!(dir.join("failed/corrupt_bad.jsonl").exists());
    let summary =
        std::fs::read_to_string(dir.join("failed/corrupt_bad.summary.json")).unwrap();
    assert!(summary.contains("error"), "failure summary carries the error: {summary}");
    assert!(spool.is_idle(), "nothing left queued or leased");
    std::fs::remove_dir_all(&dir).ok();
}
