//! Native-backend gradient checks.
//!
//! 1. Finite-difference validation of the analytic backward pass in
//!    full-precision mode, at several layer shapes / activations / LN
//!    settings: the directional derivative `⟨∇L, u⟩` along random
//!    directions must match `(L(p+εu) − L(p−εu)) / 2ε`.
//! 2. Determinism: the same `(seed, fmt, hyper)` must produce a bitwise
//!    identical loss curve across two independent runs — the property the
//!    paper's controlled comparisons (and the Fig. 7 intervention
//!    protocol) rest on.

use mxstab::coordinator::{RunConfig, Sweeper};
use mxstab::formats::spec::{hyper_idx, Fmt, FormatId};
use mxstab::runtime::native::{Activation, NativeEngine, NativeModel, ProxyConfig};
use mxstab::runtime::{Backend, StepArgs};
use mxstab::util::rng::Xoshiro256;

fn model(depth: usize, d_model: usize, act: Activation, layernorm: bool) -> NativeModel {
    NativeModel::new(ProxyConfig { depth, d_model, batch: 32, activation: act, layernorm })
        .unwrap()
}

fn step_args(fmt: Fmt, seed: i32, step: i32) -> StepArgs {
    let mut hyper = vec![0.0f32; hyper_idx::HYPER_LEN];
    hyper[hyper_idx::LR] = 1e-3;
    hyper[hyper_idx::LABEL_NOISE] = 1e-3;
    StepArgs { tokens: None, fmt: fmt.to_vec(), hyper, seed, step }
}

/// Directional finite-difference check of ∇L for every student tensor.
fn grad_check(m: &NativeModel, fmt: Fmt, tag: &str) {
    let args = step_args(fmt, 11, 3);
    let state = m.init(11, 0.0, 1.0).unwrap();
    let grads = m.grads(&state, &args).unwrap();
    let n_student = grads.len();
    let mut rng = Xoshiro256::seed_from(99);
    let eps = 1e-3f64;

    for (ti, g) in grads.iter().enumerate().take(n_student) {
        // Random unit direction for this tensor.
        let mut u = rng.normal_vec(g.len());
        let norm = (u.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()).sqrt() as f32;
        for v in &mut u {
            *v /= norm;
        }
        let analytic: f64 = g.iter().zip(&u).map(|(&gv, &uv)| gv as f64 * uv as f64).sum();

        let mut plus = state.clone();
        let mut minus = state.clone();
        for (i, &uv) in u.iter().enumerate() {
            plus.tensors[ti][i] += (eps as f32) * uv;
            minus.tensors[ti][i] -= (eps as f32) * uv;
        }
        let lp = m.loss(&plus, &args).unwrap() as f64;
        let lm = m.loss(&minus, &args).unwrap() as f64;
        let fd = (lp - lm) / (2.0 * eps);

        let tol = 2e-4 + 2e-2 * fd.abs().max(analytic.abs());
        assert!(
            (fd - analytic).abs() < tol,
            "{tag} tensor {ti}: finite-diff {fd:.6e} vs analytic {analytic:.6e} (tol {tol:.2e})"
        );
    }
}

#[test]
fn fd_gradients_gelu_ln() {
    grad_check(&model(1, 32, Activation::Gelu, true), Fmt::fp32(), "gelu/ln/L1/D32");
    grad_check(&model(2, 64, Activation::Gelu, true), Fmt::fp32(), "gelu/ln/L2/D64");
}

#[test]
fn fd_gradients_relu_and_noln() {
    grad_check(&model(2, 32, Activation::Relu, true), Fmt::fp32(), "relu/ln/L2/D32");
    grad_check(&model(1, 64, Activation::Gelu, false), Fmt::fp32(), "gelu/noln/L1/D64");
}

#[test]
fn fd_gradients_swiglu() {
    grad_check(&model(1, 32, Activation::Swiglu, true), Fmt::fp32(), "swiglu/ln/L1/D32");
}

#[test]
fn bf16_gradients_track_fp32() {
    // The all-bf16 scheme is the other "full-precision-class" mode: its
    // quantizers round (straight-through backward), so a finite-difference
    // check against the *rounded* loss is ill-posed — instead the bf16
    // gradient must agree with the FD-validated fp32 gradient to within
    // the bf16 rounding floor.
    let m = model(1, 32, Activation::Gelu, true);
    let state = m.init(5, 0.0, 1.0).unwrap();
    let g_bf16 = m
        .grads(&state, &step_args(Fmt::full(FormatId::Bf16, FormatId::Bf16), 5, 0))
        .unwrap();
    let g_fp32 = m.grads(&state, &step_args(Fmt::fp32(), 5, 0)).unwrap();
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (a, b) in g_bf16.iter().zip(&g_fp32) {
        for (&x, &y) in a.iter().zip(b) {
            dot += x as f64 * y as f64;
            na += (x as f64) * (x as f64);
            nb += (y as f64) * (y as f64);
        }
    }
    assert!(na > 0.0 && nb > 0.0);
    let cos = dot / (na.sqrt() * nb.sqrt());
    assert!(cos > 0.98, "bf16 vs fp32 gradient cosine {cos}");
    let ratio = na.sqrt() / nb.sqrt();
    assert!((0.8..1.25).contains(&ratio), "bf16/fp32 gradient norm ratio {ratio}");
}

#[test]
fn determinism_bitwise_identical_loss_curves() {
    // Same (seed, fmt, hyper) → bitwise identical trajectories, for both
    // the dense fp32 path and the packed MX path (thread-count invariant
    // by construction).
    for (label, fmt) in [
        ("fp32", Fmt::fp32()),
        ("e4m3-full", Fmt::full(FormatId::E4M3, FormatId::E4M3)),
        ("mx-mix", Fmt::mx_mix()),
    ] {
        let run = || {
            let engine = NativeEngine::with_batch(32).unwrap();
            let sweeper = Sweeper::new(engine);
            let runner = sweeper.runner("proxy_gelu_ln_L2_D32").unwrap();
            let mut cfg = RunConfig::new(&format!("det_{label}"), fmt, 1e-3, 12);
            cfg.seed = 42;
            let out = runner.run(&cfg).unwrap();
            out.log
                .rows
                .iter()
                .map(|r| (r.m.loss.to_bits(), r.m.grad_norm.to_bits()))
                .collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 12, "{label}");
        assert_eq!(a, b, "{label}: loss curve must be bitwise reproducible");
    }
}
