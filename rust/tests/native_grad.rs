//! Native-backend gradient checks (proxy + transformer LM).
//!
//! 1. Finite-difference validation of the analytic backward passes in
//!    full-precision mode, at several shapes / activations / LN settings:
//!    the directional derivative `⟨∇L, u⟩` along random directions must
//!    match `(L(p+εu) − L(p−εu)) / 2ε` for every parameter tensor — for
//!    the LM that covers the attention core (scores/softmax/values), the
//!    SwiGLU MLP, the embedding gather/scatter and the LM head.
//! 2. Determinism: the same `(seed, fmt, hyper)` must produce a bitwise
//!    identical loss curve across two independent runs — the property the
//!    paper's controlled comparisons (and the Fig. 7 intervention
//!    protocol) rest on — for both workloads, and LM token batches must
//!    be pure functions of `(seed, step)`.

use mxstab::coordinator::{RunConfig, Sweeper};
use mxstab::data::{Corpus, CorpusConfig};
use mxstab::formats::spec::{hyper_idx, Fmt, FormatId};
use mxstab::runtime::native::{
    Activation, LmConfig, LmModel, NativeEngine, NativeModel, ProxyConfig, ProxyModel,
};
use mxstab::runtime::{Backend, StepArgs};
use mxstab::util::rng::Xoshiro256;

fn proxy(depth: usize, d_model: usize, act: Activation, layernorm: bool) -> NativeModel {
    NativeModel::Proxy(
        ProxyModel::new(ProxyConfig { depth, d_model, batch: 32, activation: act, layernorm })
            .unwrap(),
    )
}

fn lm(layers: usize, d_model: usize, n_heads: usize) -> NativeModel {
    NativeModel::Lm(
        LmModel::new(LmConfig { layers, d_model, n_heads, vocab: 64, ctx: 32, batch: 2 })
            .unwrap(),
    )
}

fn step_args(fmt: Fmt, seed: i32, step: i32) -> StepArgs {
    let mut hyper = vec![0.0f32; hyper_idx::HYPER_LEN];
    hyper[hyper_idx::LR] = 1e-3;
    hyper[hyper_idx::LABEL_NOISE] = 1e-3;
    StepArgs { tokens: None, fmt: fmt.to_vec(), hyper, seed, step }
}

/// Args for an LM model: same shape, plus a deterministic token batch.
fn lm_args(m: &NativeModel, fmt: Fmt, seed: i32, step: i32) -> StepArgs {
    let vocab = m.vocab().unwrap();
    let (b, l) = m.tokens_shape().unwrap();
    let corpus = Corpus::new(CorpusConfig { vocab, ..Default::default() });
    let mut args = step_args(fmt, seed, step);
    args.tokens = Some(corpus.batch(seed as u64, step as u64, b, l));
    args
}

/// Directional finite-difference check of ∇L for every parameter tensor.
fn grad_check(m: &NativeModel, args: &StepArgs, tag: &str, eps: f64, tol0: f64) {
    let state = m.init(11, 0.0, 1.0).unwrap();
    let grads = m.grads(&state, args).unwrap();
    let mut rng = Xoshiro256::seed_from(99);

    for (ti, g) in grads.iter().enumerate() {
        // Random unit direction for this tensor.
        let mut u = rng.normal_vec(g.len());
        let norm = (u.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()).sqrt() as f32;
        for v in &mut u {
            *v /= norm;
        }
        let analytic: f64 = g.iter().zip(&u).map(|(&gv, &uv)| gv as f64 * uv as f64).sum();

        let mut plus = state.clone();
        let mut minus = state.clone();
        for (i, &uv) in u.iter().enumerate() {
            plus.tensors[ti][i] += (eps as f32) * uv;
            minus.tensors[ti][i] -= (eps as f32) * uv;
        }
        let lp = m.loss(&plus, args).unwrap() as f64;
        let lm_ = m.loss(&minus, args).unwrap() as f64;
        let fd = (lp - lm_) / (2.0 * eps);

        let tol = tol0 + 2e-2 * fd.abs().max(analytic.abs());
        assert!(
            (fd - analytic).abs() < tol,
            "{tag} tensor {ti}: finite-diff {fd:.6e} vs analytic {analytic:.6e} (tol {tol:.2e})"
        );
    }
}

#[test]
fn fd_gradients_gelu_ln() {
    let args = step_args(Fmt::fp32(), 11, 3);
    grad_check(&proxy(1, 32, Activation::Gelu, true), &args, "gelu/ln/L1/D32", 1e-3, 2e-4);
    grad_check(&proxy(2, 64, Activation::Gelu, true), &args, "gelu/ln/L2/D64", 1e-3, 2e-4);
}

#[test]
fn fd_gradients_relu_and_noln() {
    let args = step_args(Fmt::fp32(), 11, 3);
    grad_check(&proxy(2, 32, Activation::Relu, true), &args, "relu/ln/L2/D32", 1e-3, 2e-4);
    grad_check(&proxy(1, 64, Activation::Gelu, false), &args, "gelu/noln/L1/D64", 1e-3, 2e-4);
}

#[test]
fn fd_gradients_swiglu() {
    let args = step_args(Fmt::fp32(), 11, 3);
    grad_check(&proxy(1, 32, Activation::Swiglu, true), &args, "swiglu/ln/L1/D32", 1e-3, 2e-4);
}

#[test]
fn fd_gradients_lm_attention_mlp_embedding_head() {
    // One layer: attention core + SwiGLU MLP + embedding + head, every
    // tensor FD-checked. The CE loss sits near ln(V) ≈ 4.2, so the f32
    // forward rounding floor is higher than the proxy's — a slightly
    // larger ε and absolute tolerance absorb it.
    let m = lm(1, 32, 1);
    let args = lm_args(&m, Fmt::fp32(), 5, 2);
    grad_check(&m, &args, "lm/L1/D32/H1", 5e-3, 1e-3);
}

#[test]
fn fd_gradients_lm_multihead_two_layers() {
    let m = lm(2, 64, 2);
    let args = lm_args(&m, Fmt::fp32(), 6, 1);
    grad_check(&m, &args, "lm/L2/D64/H2", 5e-3, 1e-3);
}

#[test]
fn bf16_gradients_track_fp32() {
    // The all-bf16 scheme is the other "full-precision-class" mode: its
    // quantizers round (straight-through backward), so a finite-difference
    // check against the *rounded* loss is ill-posed — instead the bf16
    // gradient must agree with the FD-validated fp32 gradient to within
    // the bf16 rounding floor.
    let m = proxy(1, 32, Activation::Gelu, true);
    let state = m.init(5, 0.0, 1.0).unwrap();
    let g_bf16 = m
        .grads(&state, &step_args(Fmt::full(FormatId::Bf16, FormatId::Bf16), 5, 0))
        .unwrap();
    let g_fp32 = m.grads(&state, &step_args(Fmt::fp32(), 5, 0)).unwrap();
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (a, b) in g_bf16.iter().zip(&g_fp32) {
        for (&x, &y) in a.iter().zip(b) {
            dot += x as f64 * y as f64;
            na += (x as f64) * (x as f64);
            nb += (y as f64) * (y as f64);
        }
    }
    assert!(na > 0.0 && nb > 0.0);
    let cos = dot / (na.sqrt() * nb.sqrt());
    assert!(cos > 0.98, "bf16 vs fp32 gradient cosine {cos}");
    let ratio = na.sqrt() / nb.sqrt();
    assert!((0.8..1.25).contains(&ratio), "bf16/fp32 gradient norm ratio {ratio}");
}

#[test]
fn determinism_bitwise_identical_loss_curves() {
    // Same (seed, fmt, hyper) → bitwise identical trajectories, for both
    // the dense fp32 path and the packed MX path (thread-count invariant
    // by construction).
    for (label, fmt) in [
        ("fp32", Fmt::fp32()),
        ("e4m3-full", Fmt::full(FormatId::E4M3, FormatId::E4M3)),
        ("mx-mix", Fmt::mx_mix()),
    ] {
        let run = || {
            let engine = NativeEngine::with_batch(32).unwrap();
            let sweeper = Sweeper::new(engine);
            let runner = sweeper.runner("proxy_gelu_ln_L2_D32").unwrap();
            let mut cfg = RunConfig::new(&format!("det_{label}"), fmt, 1e-3, 12);
            cfg.seed = 42;
            let out = runner.run(&cfg).unwrap();
            out.log
                .rows
                .iter()
                .map(|r| (r.m.loss.to_bits(), r.m.grad_norm.to_bits()))
                .collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 12, "{label}");
        assert_eq!(a, b, "{label}: loss curve must be bitwise reproducible");
    }
}

#[test]
fn lm_determinism_bitwise_identical_loss_curves() {
    // The LM path adds the corpus → tokens → embedding route; the whole
    // pipeline must still be a pure function of (seed, step).
    for (label, fmt) in
        [("fp32", Fmt::fp32()), ("e4m3-full", Fmt::full(FormatId::E4M3, FormatId::E4M3))]
    {
        let run = || {
            let engine = NativeEngine::with_batch(4).unwrap();
            let sweeper = Sweeper::new(engine);
            let runner = sweeper.runner("lm_L1_D32_H1_T32_V64").unwrap();
            let mut cfg = RunConfig::new(&format!("lmdet_{label}"), fmt, 5e-3, 6);
            cfg.seed = 9;
            let out = runner.run(&cfg).unwrap();
            out.log
                .rows
                .iter()
                .map(|r| (r.m.loss.to_bits(), r.m.grad_norm.to_bits()))
                .collect::<Vec<_>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a.len(), 6, "{label}");
        assert_eq!(a, b, "{label}: LM loss curve must be bitwise reproducible");
    }
}

#[test]
fn lm_batches_are_pure_functions_of_seed_step() {
    // Two independently constructed corpora serve bitwise identical
    // (seed, step) batches — what lets every precision scheme train on
    // byte-identical LM data.
    let c1 = Corpus::new(CorpusConfig::default());
    let c2 = Corpus::new(CorpusConfig::default());
    for (seed, step) in [(0u64, 0u64), (7, 3), (42, 1000)] {
        assert_eq!(c1.batch(seed, step, 4, 65), c2.batch(seed, step, 4, 65));
    }
    assert_ne!(c1.batch(0, 0, 4, 65), c1.batch(1, 0, 4, 65), "seeds must differ");
    assert_ne!(c1.batch(0, 0, 4, 65), c1.batch(0, 1, 4, 65), "steps must differ");
}
