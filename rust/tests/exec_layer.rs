//! Execution-layer integration suite (DESIGN.md §Exec): bitwise parity of
//! the panel-decoded GEMM against the scalar oracles across adversarial
//! inputs, operand-cache invalidation across optimizer steps, and worker
//! pool behaviour under nesting and panics.

use mxstab::formats::dot::{encode, mx_dot};
use mxstab::formats::gemm::{gemm, gemm_ref, PackedMatrix};
use mxstab::formats::spec::{hyper_idx, Fmt, FormatId, BLOCK_SIZE};
use mxstab::runtime::native::{NativeEngine, NativeModel, NativeState};
use mxstab::runtime::{Backend, Engine, StepArgs};
use mxstab::util::pool;
use mxstab::util::rng::Xoshiro256;

const MX: [FormatId; 4] = [FormatId::E4M3, FormatId::E5M2, FormatId::E2M3, FormatId::E3M2];

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// An adversarial `rows × cols` matrix: Gaussian background with zero
/// blocks, f32-subnormal blocks, the paper's §6.1 clamp cluster, and
/// inf/NaN contamination sprinkled per row.
fn adversarial(rng: &mut Xoshiro256, rows: usize, cols: usize) -> Vec<f32> {
    let mut a = rng.normal_vec(rows * cols);
    let tiny = f32::from_bits(1); // smallest f32 subnormal
    for r in 0..rows {
        let row = &mut a[r * cols..(r + 1) * cols];
        match r % 5 {
            0 => row[..BLOCK_SIZE].fill(0.0), // all-zero block
            1 => {
                for (i, v) in row[..BLOCK_SIZE].iter_mut().enumerate() {
                    *v = tiny * (i as f32 + 1.0); // subnormal-only block
                }
            }
            2 => row[..BLOCK_SIZE].fill(0.897), // whole block clamps
            3 => row[0] = f32::INFINITY,
            _ => row[0] = f32::NAN,
        }
    }
    a
}

#[test]
fn panel_gemm_bitwise_equals_mx_dot_oracle_on_adversarial_inputs() {
    // The fast path must match the scalar MxBlock oracle element-for-
    // element on zero blocks, subnormals, clamp clusters and NaN/Inf
    // contamination, across same-format and mixed-format operand pairs.
    let mut rng = Xoshiro256::seed_from(17);
    let (m, n, k) = (10, 35, 96); // odd n: panel tail; m > 5: all row kinds
    let a = adversarial(&mut rng, m, k);
    let b = adversarial(&mut rng, n, k);
    let pairs = [
        (FormatId::E4M3, FormatId::E4M3),
        (FormatId::E5M2, FormatId::E5M2),
        (FormatId::E2M3, FormatId::E2M3),
        (FormatId::E3M2, FormatId::E3M2),
        (FormatId::E4M3, FormatId::E5M2),
        (FormatId::E5M2, FormatId::E2M3),
        (FormatId::E3M2, FormatId::E4M3),
    ];
    for (ida, idb) in pairs {
        let (fa, fb) = (ida.elem().unwrap(), idb.elem().unwrap());
        let am = PackedMatrix::encode(&a, m, k, ida, false);
        let bm = PackedMatrix::encode(&b, n, k, idb, false);
        let mut c = vec![0.0f32; m * n];
        gemm(&am, &bm, &mut c);
        let mut c_ref = vec![0.0f32; m * n];
        gemm_ref(&am, &bm, &mut c_ref);
        assert_eq!(bits(&c), bits(&c_ref), "{ida:?}×{idb:?}: fast vs reference kernel");
        for r in 0..m {
            let ea = encode(&a[r * k..(r + 1) * k], &fa, 0);
            for j in 0..n {
                let eb = encode(&b[j * k..(j + 1) * k], &fb, 0);
                let want = mx_dot(&ea, &eb);
                let got = c[r * n + j];
                let same = got.to_bits() == want.to_bits() || (got.is_nan() && want.is_nan());
                assert!(same, "{ida:?}×{idb:?} C[{r},{j}] = {got}, oracle {want}");
            }
        }
    }
}

#[test]
fn panel_gemm_parity_across_strip_and_tile_tails() {
    // Pool fan-out + panel tails: every (multi-strip, tail) combination
    // must stay bitwise identical to the reference kernel.
    let mut rng = Xoshiro256::seed_from(23);
    for &(m, n, k) in &[(1usize, 1usize, 32usize), (3, 64, 32), (65, 31, 64), (128, 97, 160)] {
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(n * k);
        let am = PackedMatrix::encode(&a, m, k, FormatId::E4M3, false);
        let bm = PackedMatrix::encode(&b, n, k, FormatId::E5M2, false);
        let mut c = vec![0.0f32; m * n];
        let mut c_ref = vec![0.0f32; m * n];
        gemm(&am, &bm, &mut c);
        gemm_ref(&am, &bm, &mut c_ref);
        assert_eq!(bits(&c), bits(&c_ref), "{m}x{n}x{k}");
    }
}

fn proxy_args(fmt: Fmt, step: i32) -> StepArgs {
    let mut hyper = vec![0.0f32; hyper_idx::HYPER_LEN];
    hyper[hyper_idx::LR] = 1e-2;
    hyper[hyper_idx::LABEL_NOISE] = 1e-3;
    StepArgs { tokens: None, fmt: fmt.to_vec(), hyper, seed: 5, step }
}

/// Gradients must be identical whether weight operands come warm from the
/// cache, cold from a fresh cache, or from a cache-disabled state — and a
/// post-`optimizer_step` forward must use freshly encoded weights.
#[test]
fn operand_cache_is_invisible_and_invalidated_by_optimizer_step() {
    let engine = NativeEngine::with_batch(32).unwrap();
    let model = engine.load("proxy_gelu_ln_L2_D32").unwrap();
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let args0 = proxy_args(fmt, 0);

    let state = model.init(7, 0.0, 1.0).unwrap();
    let g_cold = model.grads(&state, &args0).unwrap();
    let (hits_cold, _) = state.exec.stats();
    let g_warm = model.grads(&state, &args0).unwrap();
    let (hits_warm, _) = state.exec.stats();
    assert!(hits_warm > hits_cold, "second pass must hit the cache");
    for (a, b) in g_cold.iter().zip(&g_warm) {
        assert_eq!(bits(a), bits(b), "warm cache changed the gradients");
    }

    // One training step: weights move, version bumps, param entries drop.
    let v0 = state.exec.version();
    let (state, met) = model.step(state, &args0).unwrap();
    assert!(met.loss.is_finite());
    assert_eq!(state.exec.version(), v0 + 1, "optimizer step must bump the version");

    // Post-step gradients through the (previously warm) cache must equal
    // gradients from an identical state with caching disabled — i.e. the
    // forward used freshly encoded weights, not stale entries.
    let args1 = proxy_args(fmt, 1);
    let g_cached = model.grads(&state, &args1).unwrap();
    let fresh = NativeState::new(state.tensors.clone());
    fresh.exec.set_enabled(false);
    let g_fresh = model.grads(&fresh, &args1).unwrap();
    assert_eq!(fresh.exec.stats().0, 0, "disabled cache never hits");
    for (a, b) in g_cached.iter().zip(&g_fresh) {
        assert_eq!(bits(a), bits(b), "post-step forward must re-encode updated weights");
    }
}

#[test]
fn lm_training_is_bitwise_identical_with_and_without_cache() {
    // Three full LM steps (every projection + both attention sites, fwd
    // and bwd) under the fully-quantized scheme: the cached and the
    // cache-disabled trajectories must agree bitwise, step by step.
    let engine = NativeEngine::with_batch(2).unwrap();
    let model = engine.load("lm_L1_D32_H1_T32_V64").unwrap();
    let m = model.as_lm().unwrap();
    let corpus = mxstab::data::Corpus::new(mxstab::data::CorpusConfig {
        vocab: 64,
        ..Default::default()
    });
    let (bt, len) = model.tokens_shape().unwrap();
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);

    let mut cached = model.init(3, 0.0, 1.0).unwrap();
    let mut plain = model.init(3, 0.0, 1.0).unwrap();
    plain.exec.set_enabled(false);
    for step in 0..3i32 {
        let mut hyper = vec![0.0f32; hyper_idx::HYPER_LEN];
        hyper[hyper_idx::LR] = 1e-2;
        let args = StepArgs {
            tokens: Some(corpus.batch(1, step as u64, bt, len)),
            fmt: fmt.to_vec(),
            hyper,
            seed: 1,
            step,
        };
        let (s1, m1) = m.step(cached, &args).unwrap();
        let (s2, m2) = m.step(plain, &args).unwrap();
        assert_eq!(m1.loss.to_bits(), m2.loss.to_bits(), "step {step} loss");
        assert_eq!(m1.grad_norm.to_bits(), m2.grad_norm.to_bits(), "step {step} grad norm");
        for (a, b) in s1.tensors.iter().zip(&s2.tensors) {
            assert_eq!(bits(a), bits(b), "step {step}: state diverged");
        }
        cached = s1;
        plain = s2;
    }
    assert!(cached.exec.stats().0 > 0, "the cached trajectory must actually hit");
}

#[test]
fn pool_nests_under_parallel_gemm_calls() {
    // GEMMs large enough to fan out, issued from inside pool tasks — the
    // sweep-scheduler shape. Results must match the serial reference.
    let mut rng = Xoshiro256::seed_from(42);
    let (m, n, k) = (96, 64, 128); // m·n > PAR_MIN_OUT → inner fan-out
    let a = rng.normal_vec(m * k);
    let b = rng.normal_vec(n * k);
    let am = PackedMatrix::encode(&a, m, k, FormatId::E4M3, false);
    let bm = PackedMatrix::encode(&b, n, k, FormatId::E4M3, false);
    let mut want = vec![0.0f32; m * n];
    gemm_ref(&am, &bm, &mut want);

    let mut outs: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0f32; m * n]).collect();
    pool::scope(|s| {
        for out in outs.iter_mut() {
            let (am, bm) = (&am, &bm);
            s.spawn(move || gemm(am, bm, out));
        }
    });
    for (i, out) in outs.iter().enumerate() {
        assert_eq!(bits(out), bits(&want), "nested gemm {i}");
    }
}

#[test]
fn pool_survives_a_panicking_training_job() {
    // A panicking task inside a pool scope must not take down the pool:
    // the panic propagates to the scope caller, siblings finish, and the
    // native backend keeps training on the same pool afterwards.
    let caught = std::panic::catch_unwind(|| {
        pool::scope(|s| {
            s.spawn(|| {
                // The realistic failure: a block-misaligned encode assert.
                let misaligned = vec![0.0f32; 33];
                PackedMatrix::encode(&misaligned, 1, 33, FormatId::E4M3, false);
            });
        });
    });
    assert!(caught.is_err(), "the alignment assert must propagate");

    let engine = NativeEngine::with_batch(32).unwrap();
    let model = engine.load("proxy_gelu_ln_L1_D32").unwrap();
    let state = model.init(0, 0.0, 1.0).unwrap();
    let (_, met) =
        model.step(state, &proxy_args(Fmt::full(FormatId::E4M3, FormatId::E4M3), 0)).unwrap();
    assert!(met.loss.is_finite(), "pool still serves training after the panic");
}

#[test]
fn clone_and_restore_reset_the_cache() {
    let engine = NativeEngine::with_batch(32).unwrap();
    let model = engine.load("proxy_gelu_ln_L1_D32").unwrap();
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let state = model.init(2, 0.0, 1.0).unwrap();
    model.grads(&state, &proxy_args(fmt, 0)).unwrap(); // warm the cache

    // clone_state: fresh cache — mutating the clone's tensors afterwards
    // (finite-difference probes do this) can never see stale entries.
    let cloned = model.clone_state(&state).unwrap();
    assert_eq!(cloned.exec.stats(), (0, 0), "clone starts with an empty cache");

    // The cache-off flag propagates through clone (baseline runs stay off).
    let off = NativeState::new(state.tensors.clone());
    off.exec.set_enabled(false);
    assert!(!off.clone().exec.enabled(), "disabled flag survives clone");

    // snapshot → restore: also a fresh cache.
    let restored = model.restore(model.snapshot(&state).unwrap()).unwrap();
    assert_eq!(restored.exec.stats(), (0, 0), "restore starts with an empty cache");
    for (a, b) in restored.tensors.iter().zip(&state.tensors) {
        assert_eq!(bits(a), bits(b), "tensors roundtrip bitwise");
    }
}

#[test]
fn native_model_enum_exposes_lm_accessor() {
    // Regression guard for the test-suite plumbing above.
    let engine = NativeEngine::new();
    let lm = engine.load("lm_olmo_1m").unwrap();
    assert!(lm.as_lm().is_some());
    assert!(lm.as_proxy().is_none());
    let proxy = engine.load("proxy_gelu_ln_L2_D64").unwrap();
    assert!(matches!(proxy.as_ref(), NativeModel::Proxy(_)));
}
