//! Property tests for the packed MX engine (ISSUE 1 acceptance bar):
//!
//! 1. `decode(encode(x))` through the packed codec is **bit-identical** to
//!    the scalar `mx_qdq` for every `FormatId`, over random inputs and the
//!    adversarial families the paper's §6.1 analysis cares about —
//!    subnormals (both format- and f32-level), all-zero blocks, tight
//!    clamp-region clusters, ±0, and huge-dynamic-range blocks.
//! 2. The packed block GEMM matches the scalar `emulated_dot` oracle to
//!    f32 round-off (and `mx_dot` bitwise, since it reproduces its
//!    accumulation order).

use mxstab::formats::dot::{emulated_dot, encode, mx_dot};
use mxstab::formats::gemm::{gemm, matvec, PackedMatrix};
use mxstab::formats::quant::mx_qdq;
use mxstab::formats::{packed_qdq, FormatId, PackedVec, BLOCK_SIZE};
use mxstab::util::prop;
use mxstab::util::rng::Xoshiro256;

const MX: [FormatId; 4] = [FormatId::E4M3, FormatId::E5M2, FormatId::E2M3, FormatId::E3M2];

fn assert_bitwise(tag: &str, want: &[f32], got: &[f32]) {
    assert_eq!(want.len(), got.len(), "{tag}: length");
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        let same = w.to_bits() == g.to_bits() || (w.is_nan() && g.is_nan());
        assert!(same, "{tag}[{i}]: scalar {w} ({:#010x}) vs packed {g} ({:#010x})",
            w.to_bits(), g.to_bits());
    }
}

#[test]
fn random_inputs_roundtrip_bit_identical_for_every_format() {
    prop::forall("packed-roundtrip", 200, |rng| {
        let x = prop::gen_f32_vec(rng, 160);
        for id in FormatId::ALL {
            for bump in [false, true] {
                let (want, cw) = mx_qdq(&x, id, bump);
                let (got, cg) = packed_qdq(&x, id, bump);
                if cw != cg {
                    return Err(format!("{id:?} bump={bump}: clamp {cw} vs {cg}"));
                }
                for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                    if w.to_bits() != g.to_bits() {
                        return Err(format!(
                            "{id:?} bump={bump} [{i}]: {w} vs {g} (input {})",
                            x[i]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn adversarial_families_roundtrip_bit_identical() {
    let tiny = f32::from_bits(1); // smallest f32 subnormal
    let cases: Vec<(&str, Vec<f32>)> = vec![
        ("all-zero", vec![0.0; 2 * BLOCK_SIZE]),
        ("neg-zero", vec![-0.0; BLOCK_SIZE]),
        ("f32-subnormal-block", (0..BLOCK_SIZE).map(|i| tiny * (1 + i) as f32).collect()),
        (
            "format-subnormal-ramp",
            (0..2 * BLOCK_SIZE).map(|i| 2.0f32.powi(-9) * 0.26 * i as f32).collect(),
        ),
        ("clamp-cluster", vec![0.897; BLOCK_SIZE]), // paper §6.1: whole block clamps
        (
            "clamp-threshold-straddle",
            (0..BLOCK_SIZE).map(|i| 1.9 * (0.85 + 0.005 * i as f32)).collect(),
        ),
        (
            "wide-dynamic-range",
            (0..BLOCK_SIZE).map(|i| (-1.0f32).powi(i as i32) * 2.0f32.powi(i as i32 - 16)).collect(),
        ),
        ("huge-and-tiny", {
            let mut v = vec![1e-39f32; BLOCK_SIZE];
            v[7] = 3.0e38;
            v[8] = -3.0e38;
            v
        }),
        ("single-nonzero", {
            let mut v = vec![0.0f32; 2 * BLOCK_SIZE];
            v[40] = -5.5e-5;
            v
        }),
    ];
    for (tag, x) in &cases {
        for id in MX {
            for bump in [false, true] {
                let (want, cw) = mx_qdq(x, id, bump);
                let (got, cg) = packed_qdq(x, id, bump);
                assert_eq!(cw, cg, "{tag}/{id:?}/bump={bump}: clamp count");
                assert_bitwise(&format!("{tag}/{id:?}/bump={bump}"), &want, &got);
            }
        }
    }
}

#[test]
fn shrinking_localizes_any_future_divergence() {
    // Meta-check that the shrinker composes with the roundtrip property:
    // build a deliberately failing predicate over a passing input to show
    // shrink_vec terminates and preserves block alignment usage here.
    let fails = |v: &[f32]| {
        v.len() % BLOCK_SIZE == 0
            && !v.is_empty()
            && {
                let (a, _) = mx_qdq(v, FormatId::E4M3, false);
                let (b, _) = packed_qdq(v, FormatId::E4M3, false);
                a.iter().zip(&b).any(|(x, y)| x.to_bits() != y.to_bits())
            }
    };
    let mut rng = Xoshiro256::seed_from(99);
    let x = rng.normal_vec(4 * BLOCK_SIZE);
    assert!(!fails(&x), "roundtrip must not diverge");
    let shrunk = prop::shrink_vec(x, fails);
    assert!(!shrunk.is_empty());
}

#[test]
fn packed_gemm_matches_emulated_dot_to_roundoff() {
    prop::forall("gemm≡emulated", 24, |rng| {
        let (m, n, k) = (5, 7, 64);
        let a = prop::gen_f32_vec(rng, m * k);
        let b = prop::gen_f32_vec(rng, n * k);
        for id in MX {
            let f = id.elem().unwrap();
            let am = PackedMatrix::encode(&a, m, k, id, false);
            let bm = PackedMatrix::encode(&b, n, k, id, false);
            let mut c = vec![0.0f32; m * n];
            gemm(&am, &bm, &mut c);
            for r in 0..m {
                let ea = encode(&a[r * k..(r + 1) * k], &f, 0);
                for j in 0..n {
                    let eb = encode(&b[j * k..(j + 1) * k], &f, 0);
                    let want_emu = emulated_dot(&ea, &eb);
                    let want_mx = mx_dot(&ea, &eb);
                    let got = c[r * n + j];
                    // Bitwise vs the scale-carried oracle...
                    if got.to_bits() != want_mx.to_bits() {
                        return Err(format!("{id:?} C[{r},{j}]: {got} vs mx_dot {want_mx}"));
                    }
                    // ...and round-off-level vs the dequantize-first path.
                    let denom = want_emu.abs().max(1e-20);
                    if ((got as f64 - want_emu as f64) / denom as f64).abs() > 1e-5 {
                        return Err(format!(
                            "{id:?} C[{r},{j}]: {got} vs emulated {want_emu}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn packed_matvec_matches_oracle_on_tall_matrices() {
    let mut rng = Xoshiro256::seed_from(1234);
    // Tall enough to engage the thread fan-out path in matvec.
    let (rows, cols) = (300, 256);
    let a = rng.normal_vec(rows * cols);
    let x = rng.normal_vec(cols);
    for id in MX {
        let f = id.elem().unwrap();
        let xb = encode(&x, &f, 0);
        let am = PackedMatrix::encode(&a, rows, cols, id, false);
        let xv = PackedVec::encode(&x, id, false);
        let got = matvec(&am, &xv);
        for r in 0..rows {
            let want = mx_dot(&encode(&a[r * cols..(r + 1) * cols], &f, 0), &xb);
            assert_eq!(got[r].to_bits(), want.to_bits(), "{id:?} row {r}");
        }
    }
}

#[test]
fn packed_encoding_is_dense() {
    // The codec's reason to exist: 4 bytes/elem → ~1.06 bytes/elem.
    let x = vec![1.0f32; 1024];
    let p = PackedVec::encode(&x, FormatId::E4M3, false);
    assert_eq!(p.bytes(), 1024 + 2 * (1024 / BLOCK_SIZE));
    assert!(p.bytes() * 3 < std::mem::size_of_val(&x[..]));
}
