//! Coordinator-level integration tests that do not require artifacts:
//! detector + run-log + intervention + sweep machinery end to end, plus
//! the full coordinator stack over the **native backend** — training
//! loops, mid-run fmt-vector interventions, checkpoint rings and sweeps
//! all run on a bare machine (artifact-backed PJRT paths are covered by
//! `runtime_artifacts.rs`).

use mxstab::coordinator::{
    CheckpointStore, Detector, DetectorConfig, Intervention, Job, LrSchedule, Policy, RunConfig,
    RunLog, Sweeper, Verdict,
};
use mxstab::formats::spec::{Fmt, FormatId};
use mxstab::runtime::{Backend, Metrics, NativeEngine};

fn metrics(loss: f32, gnorm: f32) -> Metrics {
    Metrics { loss, grad_norm: gnorm, ..Default::default() }
}

/// Simulate the paper's Fig. 1b shape: grad norm climbs slowly, then the
/// loss lets go and never recovers — the detector must (a) not fire during
/// the climb, (b) flag the spike, (c) declare divergence soon after.
#[test]
fn detector_tracks_fig1b_shape() {
    let mut d = Detector::new(DetectorConfig::default());
    let mut log = RunLog::new("fig1b");
    let mut verdicts = vec![];
    for t in 0..600usize {
        let (loss, g) = if t < 400 {
            (1.0 / (1.0 + t as f64 * 0.01), 1.0 + t as f64 * 0.004)
        } else {
            // runaway: loss ×1.5 per step, grad norm climbing with it
            (
                0.25 * 1.5f64.powi((t - 400) as i32 + 1),
                10.0 * 1.05f64.powi((t - 400) as i32),
            )
        };
        let v = d.push(loss, g);
        verdicts.push(v);
        log.push(t, metrics(loss as f32, g as f32));
    }
    assert!(verdicts[..400].iter().all(|v| *v == Verdict::Healthy));
    assert!(d.diverged());
    let dv = d.diverged_at.unwrap();
    assert!((400..470).contains(&dv), "diverged at {dv}");
    assert!(d.grad_growth() > 1.0);
    // A gradual ×1.5/step runaway never makes a single ≥100× jump — the
    // EWMA divergence rule must catch it even with zero spike events.
    assert_eq!(d.spikes, 0);
}

#[test]
fn policy_menu_matches_paper_fig7() {
    // Every paper intervention must be representable and produce a fmt
    // distinct from the baseline.
    let base = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let mut seen = std::collections::HashSet::new();
    for iv in Intervention::ALL {
        let f = iv.apply(base);
        assert_ne!(f, base, "{iv:?} must change the scheme");
        seen.insert(format!("{f:?}"));
    }
    assert_eq!(seen.len(), Intervention::ALL.len(), "interventions are distinct");
}

#[test]
fn grad_growth_trigger_fires_before_fixed_step() {
    let fixed = Policy::at_step(500, Intervention::ToFp32);
    let auto = Policy::on_grad_growth(3.0, Intervention::Bf16Act);
    let mut d = Detector::new(DetectorConfig::default());
    let mut auto_fired_at = None;
    for t in 0..600usize {
        // 3%/step climb → window ratio 1.03^50 ≈ 4.4 crosses the 3.0 trigger
        let g = 1.0 * 1.03f64.powi(t as i32);
        d.push(0.5, g);
        if auto_fired_at.is_none() && auto.fires(t, d.grad_growth()) {
            auto_fired_at = Some(t);
        }
        if fixed.fires(t, d.grad_growth()) {
            break;
        }
    }
    let at = auto_fired_at.expect("auto trigger fired");
    assert!(at < 500, "grad-growth trigger should beat the fixed step, fired at {at}");
}

#[test]
fn runlog_roundtrip_preserves_series() {
    let dir = std::env::temp_dir().join(format!("mxstab_coord_{}", std::process::id()));
    let mut log = RunLog::new("roundtrip");
    log.meta.push(("fmt".into(), "e4m3-e4m3".into()));
    for t in 0..50 {
        log.push(
            t,
            Metrics {
                loss: (50 - t) as f32 * 0.01,
                grad_norm: 1.0 + t as f32 * 0.1,
                eps_ratio: 0.1,
                cosine: 0.99,
                ..Default::default()
            },
        );
    }
    log.interventions.push((25, "bf16-act".into()));
    log.save(&dir).unwrap();
    let back = RunLog::load(&dir, "roundtrip").unwrap();
    assert_eq!(back.rows.len(), 50);
    assert_eq!(back.losses(), log.losses());
    assert_eq!(back.grad_norms(), log.grad_norms());
    assert_eq!(back.series(|m| m.cosine), log.series(|m| m.cosine));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lr_schedule_monotonic_in_phases() {
    let s = LrSchedule::WarmupCosine { lo: 1e-5, peak: 1e-3, warmup: 50, total: 500 };
    for t in 1..50 {
        assert!(s.at(t) >= s.at(t - 1), "warmup must be nondecreasing");
    }
    for t in 51..500 {
        assert!(s.at(t) <= s.at(t - 1) + 1e-9, "decay must be nonincreasing");
    }
}

#[test]
fn runconfig_defaults_are_papers() {
    let cfg = RunConfig::new("x", Fmt::fp32(), 5e-4, 100);
    assert_eq!(cfg.label_noise, 1e-3, "paper's σ for the proxy targets");
    assert_eq!(cfg.init_gain, 1.0);
    assert!(!cfg.paired);
    assert!(cfg.policies.is_empty());
}

// ---------------------------------------------------------------------------
// Native-backend end-to-end: the full coordinator without PJRT.
// ---------------------------------------------------------------------------

#[test]
fn native_runner_trains_end_to_end() {
    let sweeper = Sweeper::new(NativeEngine::with_batch(32).unwrap());
    let runner = sweeper.runner("proxy_gelu_ln_L2_D32").unwrap();
    let mut cfg = RunConfig::new("native_e2e", Fmt::full(FormatId::E4M3, FormatId::E4M3), 1e-3, 25);
    cfg.paired = true; // native backend supports the Fig. 4 diagnostics
    let out = runner.run(&cfg).unwrap();
    assert_eq!(out.log.rows.len(), 25);
    for r in &out.log.rows {
        assert!(r.m.loss.is_finite() && r.m.grad_norm.is_finite(), "step {}", r.step);
        assert!(r.m.param_norm > 0.0 && r.m.update_norm > 0.0);
    }
    assert!(out.final_state.is_some());
}

#[test]
fn native_intervention_flips_fmt_mid_run() {
    // The paper's Fig. 7 protocol on the native backend: an AtStep policy
    // rewrites the fmt vector between steps; the run log records it and
    // the LN-clamping diagnostic must react on the very next step.
    let sweeper = Sweeper::new(NativeEngine::with_batch(32).unwrap());
    let runner = sweeper.runner("proxy_gelu_ln_L2_D32").unwrap();

    // Force the §6.1 pathology so ln_frac is a crisp on/off signal.
    let backend = runner.backend.clone();
    let mut state = backend.init(0, 0.0, 1.0).unwrap();
    let ln_idx = 2usize; // [w1, w2, ln]
    for v in &mut state.tensors[ln_idx] {
        *v = 0.9;
    }

    let mut cfg = RunConfig::new("native_iv", Fmt::full(FormatId::E4M3, FormatId::E4M3), 1e-4, 10);
    cfg.policies = vec![Policy::at_step(5, Intervention::SkipLnQuant)];
    let out = runner.run_from(&cfg, state, 0).unwrap();
    assert_eq!(out.log.interventions, vec![(5usize, "skip-ln-quant".to_string())]);
    let frac = |step: usize| {
        out.log.rows.iter().find(|r| r.step == step).map(|r| r.m.ln_frac_mean).unwrap()
    };
    assert!(frac(4) > 0.5, "pre-intervention: clustered gammas clamp ({})", frac(4));
    assert_eq!(frac(5), 0.0, "post-intervention: LN quantization off");
    assert_eq!(frac(9), 0.0, "stays off for the rest of the run");
}

#[test]
fn native_checkpoint_roundtrip_and_ring() {
    let engine = NativeEngine::with_batch(32).unwrap();
    let sweeper = Sweeper::new(engine);
    let runner = sweeper.runner("proxy_relu_ln_L2_D32").unwrap();
    let backend = runner.backend.clone();

    let dir = std::env::temp_dir().join(format!("mxstab_ckpt_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = CheckpointStore::new(&dir, 2);

    let cfg = RunConfig::new("ckpt", Fmt::fp32(), 1e-3, 5);
    let out = runner.run(&cfg).unwrap();
    let state = out.final_state.unwrap();
    store.save(backend.as_ref(), "run0", 5, &state).unwrap();
    store.save(backend.as_ref(), "run0", 10, &state).unwrap();
    store.save(backend.as_ref(), "run0", 15, &state).unwrap();
    assert_eq!(store.list("run0"), vec![10, 15], "ring keeps the newest 2");
    assert_eq!(store.latest("run0"), Some(15));

    let restored = store.load(backend.as_ref(), "run0", 15).unwrap();
    assert_eq!(restored.tensors, state.tensors, "bitwise state roundtrip");

    // Restored state must continue training identically to the original.
    let mut cont = RunConfig::new("cont", Fmt::fp32(), 1e-3, 8);
    cont.seed = cfg.seed;
    let a = runner.run_from(&cont, state, 5).unwrap();
    let b = runner.run_from(&cont, restored, 5).unwrap();
    let bits = |l: &RunLog| l.rows.iter().map(|r| r.m.loss.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&a.log), bits(&b.log));

    // Cross-model restores are rejected.
    let other = sweeper.backend("proxy_relu_ln_L2_D64").unwrap();
    assert!(store.load(other.as_ref(), "run0", 15).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn native_lm_runner_trains_and_evals_end_to_end() {
    // The transformer-LM workload through the full coordinator: Sweeper
    // builds the Zipf–Markov corpus from the model's vocab, Runner feeds
    // (seed, step) token batches, and the Backend eval returns a finite
    // held-out validation loss — all fully quantized, no PJRT.
    let sweeper = Sweeper::new(NativeEngine::with_batch(4).unwrap());
    let runner = sweeper.runner("lm_L2_D64_H2_T32_V256").unwrap();
    assert!(runner.corpus.is_some(), "LM runner must build a corpus");
    let mut cfg =
        RunConfig::new("native_lm_e2e", Fmt::full(FormatId::E4M3, FormatId::E4M3), 2e-3, 10);
    cfg.seed = 1;
    let out = runner.run(&cfg).unwrap();
    assert_eq!(out.log.rows.len(), 10);
    for r in &out.log.rows {
        assert!(r.m.loss.is_finite() && r.m.grad_norm.is_finite(), "step {}", r.step);
        assert!(r.m.param_norm > 0.0 && r.m.update_norm > 0.0);
    }
    let state = out.final_state.unwrap();
    let corpus = runner.corpus.clone().unwrap();
    let (b, l) = runner.backend.tokens_shape().unwrap();
    let toks = corpus.batch(mxstab::data::HELD_OUT_SEED, 0, b, l);
    let val = runner.backend.eval(&state, &toks, &cfg.fmt.to_vec()).unwrap();
    assert!(val.is_finite(), "validation loss {val}");
}

#[test]
fn native_lm_checkpoint_restores_bitwise() {
    let sweeper = Sweeper::new(NativeEngine::with_batch(2).unwrap());
    let runner = sweeper.runner("lm_L1_D32_H1_T32_V64").unwrap();
    let backend = runner.backend.clone();
    let dir = std::env::temp_dir().join(format!("mxstab_lmckpt_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = CheckpointStore::new(&dir, 1);

    let cfg = RunConfig::new("lmckpt", Fmt::fp32(), 1e-3, 4);
    let out = runner.run(&cfg).unwrap();
    let state = out.final_state.unwrap();
    store.save(backend.as_ref(), "lm0", 4, &state).unwrap();
    let restored = store.load(backend.as_ref(), "lm0", 4).unwrap();
    assert_eq!(restored.tensors, state.tensors, "bitwise LM state roundtrip");

    // Restored state must continue training identically to the original.
    let mut cont = RunConfig::new("lmcont", Fmt::fp32(), 1e-3, 7);
    cont.seed = cfg.seed;
    let a = runner.run_from(&cont, state, 4).unwrap();
    let b = runner.run_from(&cont, restored, 4).unwrap();
    let bits = |l: &RunLog| l.rows.iter().map(|r| r.m.loss.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&a.log), bits(&b.log));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn native_sweeper_runs_jobs_in_order() {
    let sweeper = Sweeper::new(NativeEngine::with_batch(32).unwrap());
    let jobs: Vec<Job> = [
        ("fp32", Fmt::fp32()),
        ("e4m3", Fmt::full(FormatId::E4M3, FormatId::E4M3)),
        ("mix", Fmt::mx_mix()),
    ]
    .into_iter()
    .map(|(label, fmt)| Job {
        bundle: "proxy_gelu_ln_L2_D32".into(),
        cfg: RunConfig::new(label, fmt, 1e-3, 6),
    })
    .collect();
    let logs = sweeper.run_all(&jobs, true);
    assert_eq!(logs.len(), 3);
    for (log, job) in logs.iter().zip(&jobs) {
        assert_eq!(log.name, job.cfg.name, "submission order preserved");
        assert_eq!(log.rows.len(), 6);
        assert!(log.final_loss().is_finite());
    }
    // Unknown bundle names degrade to error-marked logs, not a panic.
    let bad_cfg = RunConfig::new("bad", Fmt::fp32(), 1e-3, 2);
    let bad = vec![Job { bundle: "lm_nope".into(), cfg: bad_cfg }];
    let logs = sweeper.run_all(&bad, true);
    assert_eq!(logs.len(), 1);
    assert!(logs[0].rows.is_empty());
    assert!(logs[0].meta.iter().any(|(k, _)| k == "error"));
}
