//! Coordinator-level integration tests that do not require artifacts:
//! detector + run-log + intervention + sweep-cache machinery end to end
//! (artifact-backed paths are covered by `runtime_artifacts.rs`).

use mxstab::coordinator::{
    Detector, DetectorConfig, Intervention, LrSchedule, Policy, RunConfig, RunLog, Verdict,
};
use mxstab::formats::spec::{Fmt, FormatId};
use mxstab::runtime::Metrics;

fn metrics(loss: f32, gnorm: f32) -> Metrics {
    Metrics { loss, grad_norm: gnorm, ..Default::default() }
}

/// Simulate the paper's Fig. 1b shape: grad norm climbs slowly, then the
/// loss lets go and never recovers — the detector must (a) not fire during
/// the climb, (b) flag the spike, (c) declare divergence soon after.
#[test]
fn detector_tracks_fig1b_shape() {
    let mut d = Detector::new(DetectorConfig::default());
    let mut log = RunLog::new("fig1b");
    let mut verdicts = vec![];
    for t in 0..600usize {
        let (loss, g) = if t < 400 {
            (1.0 / (1.0 + t as f64 * 0.01), 1.0 + t as f64 * 0.004)
        } else {
            // runaway: loss ×1.5 per step, grad norm climbing with it
            (
                0.25 * 1.5f64.powi((t - 400) as i32 + 1),
                10.0 * 1.05f64.powi((t - 400) as i32),
            )
        };
        let v = d.push(loss, g);
        verdicts.push(v);
        log.push(t, metrics(loss as f32, g as f32));
    }
    assert!(verdicts[..400].iter().all(|v| *v == Verdict::Healthy));
    assert!(d.diverged());
    let dv = d.diverged_at.unwrap();
    assert!((400..470).contains(&dv), "diverged at {dv}");
    assert!(d.grad_growth() > 1.0);
    // A gradual ×1.5/step runaway never makes a single ≥100× jump — the
    // EWMA divergence rule must catch it even with zero spike events.
    assert_eq!(d.spikes, 0);
}

#[test]
fn policy_menu_matches_paper_fig7() {
    // Every paper intervention must be representable and produce a fmt
    // distinct from the baseline.
    let base = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let mut seen = std::collections::HashSet::new();
    for iv in Intervention::ALL {
        let f = iv.apply(base);
        assert_ne!(f, base, "{iv:?} must change the scheme");
        seen.insert(format!("{f:?}"));
    }
    assert_eq!(seen.len(), Intervention::ALL.len(), "interventions are distinct");
}

#[test]
fn grad_growth_trigger_fires_before_fixed_step() {
    let fixed = Policy::at_step(500, Intervention::ToFp32);
    let auto = Policy::on_grad_growth(3.0, Intervention::Bf16Act);
    let mut d = Detector::new(DetectorConfig::default());
    let mut auto_fired_at = None;
    for t in 0..600usize {
        // 3%/step climb → window ratio 1.03^50 ≈ 4.4 crosses the 3.0 trigger
        let g = 1.0 * 1.03f64.powi(t as i32);
        d.push(0.5, g);
        if auto_fired_at.is_none() && auto.fires(t, d.grad_growth()) {
            auto_fired_at = Some(t);
        }
        if fixed.fires(t, d.grad_growth()) {
            break;
        }
    }
    let at = auto_fired_at.expect("auto trigger fired");
    assert!(at < 500, "grad-growth trigger should beat the fixed step, fired at {at}");
}

#[test]
fn runlog_roundtrip_preserves_series() {
    let dir = std::env::temp_dir().join(format!("mxstab_coord_{}", std::process::id()));
    let mut log = RunLog::new("roundtrip");
    log.meta.push(("fmt".into(), "e4m3-e4m3".into()));
    for t in 0..50 {
        log.push(
            t,
            Metrics {
                loss: (50 - t) as f32 * 0.01,
                grad_norm: 1.0 + t as f32 * 0.1,
                eps_ratio: 0.1,
                cosine: 0.99,
                ..Default::default()
            },
        );
    }
    log.interventions.push((25, "bf16-act".into()));
    log.save(&dir).unwrap();
    let back = RunLog::load(&dir, "roundtrip").unwrap();
    assert_eq!(back.rows.len(), 50);
    assert_eq!(back.losses(), log.losses());
    assert_eq!(back.grad_norms(), log.grad_norms());
    assert_eq!(back.series(|m| m.cosine), log.series(|m| m.cosine));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn lr_schedule_monotonic_in_phases() {
    let s = LrSchedule::WarmupCosine { lo: 1e-5, peak: 1e-3, warmup: 50, total: 500 };
    for t in 1..50 {
        assert!(s.at(t) >= s.at(t - 1), "warmup must be nondecreasing");
    }
    for t in 51..500 {
        assert!(s.at(t) <= s.at(t - 1) + 1e-9, "decay must be nonincreasing");
    }
}

#[test]
fn runconfig_defaults_are_papers() {
    let cfg = RunConfig::new("x", Fmt::fp32(), 5e-4, 100);
    assert_eq!(cfg.label_noise, 1e-3, "paper's σ for the proxy targets");
    assert_eq!(cfg.init_gain, 1.0);
    assert!(!cfg.paired);
    assert!(cfg.policies.is_empty());
}
