//! Analysis-pipeline integration: synthetic end-to-end flows through the
//! scaling fitter, spike census, gradient-bias summarizer and the report
//! sink — the machinery behind every regenerated table/figure.

use mxstab::analysis::spikes::count_spikes;
use mxstab::analysis::{fit_chinchilla, gradbias, LossPoint};
use mxstab::coordinator::RunLog;
use mxstab::data::{Corpus, CorpusConfig};
use mxstab::report::Report;
use mxstab::runtime::Metrics;
use mxstab::util::rng::Xoshiro256;
use mxstab::util::svg::{Plot, Series, PALETTE};
use mxstab::util::table::Table;

/// Generate a Chinchilla surface with the paper's Table-2-like constants,
/// sprinkle one diverged run, and require the fitter to recover the
/// exponents and the optimal-size exponent a = β/(α+β).
#[test]
fn table2_like_fit_recovers_constants() {
    let (a, b, e, alpha, beta) = (1.94e3, 2.18e4, 0.53, 0.50, 0.56);
    let mut rng = Xoshiro256::seed_from(1);
    let mut pts = vec![];
    for &n in &[2e5f64, 6e5, 1.8e6, 5e6] {
        for &r in &[2.0, 8.0, 32.0, 128.0] {
            let d = n * r;
            let loss = e + a / n.powf(alpha) + b / d.powf(beta);
            pts.push(LossPoint { n_params: n, tokens: d, loss: loss * (1.0 + 0.005 * rng.normal()) });
        }
    }
    pts.push(LossPoint { n_params: 6e5, tokens: 6e6, loss: 23.0 }); // diverged outlier
    let fit = fit_chinchilla(&pts);
    assert!((fit.alpha - alpha).abs() < 0.12, "alpha {}", fit.alpha);
    assert!((fit.beta - beta).abs() < 0.12, "beta {}", fit.beta);
    let a_exp = beta / (alpha + beta);
    assert!((fit.opt_exponent - a_exp).abs() < 0.1, "a {}", fit.opt_exponent);
}

/// The Fig. 4 postprocessing on a synthetic ζ-bound series with the paper's
/// shape (drift down → turn-around → cross 2 → divergence).
#[test]
fn gradbias_pipeline_on_paper_shape() {
    let mut log = RunLog::new("fig4-synth");
    for t in 0..1000usize {
        let eps = if t < 300 {
            0.3 - 0.0008 * t as f64
        } else {
            0.06 * 1.012f64.powi((t - 300) as i32)
        };
        let cos = (1.0 - eps / 3.0).max(0.0);
        log.push(
            t,
            Metrics {
                loss: 0.1,
                grad_norm: 1.0,
                eps_ratio: eps as f32,
                cosine: cos as f32,
                ..Default::default()
            },
        );
    }
    let s = gradbias::summarize(&log, 0.1, 2.0);
    let ta = s.turnaround_step.unwrap();
    assert!((250..420).contains(&ta), "turnaround {ta}");
    let cx = s.crossing_step.unwrap();
    assert!(cx > 550, "crossing {cx}");
    assert!(s.cosine.last().unwrap() < &0.5);
}

/// Spike census + report rendering end to end (Fig. 9 pipeline shape).
#[test]
fn fig9_pipeline_renders() {
    let dir = std::env::temp_dir().join(format!("mxstab_an_{}", std::process::id()));
    let mut rep = Report::new(&dir, "fig9-test").unwrap();
    let mut table = Table::new(&["cell", "spikes"]);
    let mut rng = Xoshiro256::seed_from(2);
    for cell in 0..6 {
        let mut loss = 1.0f64;
        let series: Vec<f64> = (0..2000)
            .map(|_| {
                loss *= 0.999;
                if rng.next_f64() < 0.002 {
                    loss * 300.0
                } else {
                    loss
                }
            })
            .collect();
        table.row(vec![format!("c{cell}"), count_spikes(&series, 100.0).to_string()]);
    }
    rep.table("census", &table).unwrap();
    let mut p = Plot::new("t", "x", "y").logy();
    p.add(Series::line("s", vec![1.0, 2.0], vec![0.5, 0.1], PALETTE[0]));
    rep.plot("fig", &p).unwrap();
    let md = rep.finish().unwrap();
    let text = std::fs::read_to_string(&md).unwrap();
    assert!(text.contains("census") == false || !text.is_empty());
    assert!(dir.join("fig9-test/census.csv").exists());
    assert!(dir.join("fig9-test/fig.svg").exists());
    std::fs::remove_dir_all(&dir).ok();
}

/// The synthetic corpus must give an LM something to learn: conditional
/// entropy strictly below unigram entropy, both bounded by log vocab.
#[test]
fn corpus_entropy_budget() {
    for vocab in [256usize, 512] {
        let c = Corpus::new(CorpusConfig { vocab, ..Default::default() });
        let hu = c.unigram_entropy();
        let hc = c.conditional_entropy();
        let hmax = (vocab as f64).ln();
        assert!(hu < hmax, "unigram {hu} < log V {hmax}");
        assert!(hc < hu, "markov structure must help: {hc} vs {hu}");
        assert!(hc > 1.0, "not degenerate");
    }
}

/// Empirical bigram statistics of sampled batches should reflect the
/// Markov kernel (row-dependent shift), not just the unigram.
#[test]
fn corpus_bigram_structure_is_learnable() {
    let c = Corpus::new(CorpusConfig::default());
    let toks = c.batch(1, 0, 64, 256);
    // Count P(next | prev mod rows == 0) vs global unigram: the shifted
    // rows put mass on different tokens.
    let mut cond = vec![0f64; 512];
    let mut glob = vec![0f64; 512];
    let mut n_cond = 0.0;
    for seq in toks.chunks(256) {
        for w in seq.windows(2) {
            glob[w[1] as usize] += 1.0;
            if (w[0] as usize) % 16 == 5 {
                cond[w[1] as usize] += 1.0;
                n_cond += 1.0;
            }
        }
    }
    let total: f64 = glob.iter().sum();
    // L1 distance between conditional and marginal next-token distributions.
    let l1: f64 = cond
        .iter()
        .zip(&glob)
        .map(|(c, g)| (c / n_cond - g / total).abs())
        .sum();
    assert!(l1 > 0.3, "conditional should differ from marginal (L1 {l1})");
}
