//! Adversarial integration tests for the rollback-and-escalate
//! stabilization guard: deterministic divergence injection, bitwise
//! rollback-replay proofs, quarantine terminal states, and crash parity
//! through a recovery (a worker killed *mid-replay* must resume to a
//! byte-identical log and flight recorder).
//!
//! All tests share one process (cargo runs them on parallel threads), so
//! every test uses scope-unique run names / worker ids and clears its
//! faults on exit — the fault registry only fires on matching scopes.

use std::path::PathBuf;

use mxstab::coordinator::metrics::Row;
use mxstab::coordinator::{
    run_worker, GuardConfig, Intervention, Job, Policy, RunConfig, RunLog, Spool, Sweeper,
    WorkerConfig,
};
use mxstab::formats::spec::{Fmt, FormatId};
use mxstab::runtime::NativeEngine;
use mxstab::util::faults::{self, Fault, FaultAction};

const BUNDLE: &str = "lm_L1_D32_H1_T32_V64";

fn sweeper() -> Sweeper<NativeEngine> {
    Sweeper::new(NativeEngine::with_batch(2).unwrap())
}

fn lm_cfg(name: &str, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::new(name, Fmt::full(FormatId::E4M3, FormatId::E4M3), 1e-3, steps);
    cfg.log_every = 1;
    cfg
}

fn tdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("mxstab_guard_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

/// Rows with the rung tag dropped, for bitwise comparison against an
/// unguarded oracle (the guard legitimately tags replayed rows).
fn strip_rungs(rows: &[Row]) -> Vec<Row> {
    rows.iter().map(|r| Row { rung: None, ..*r }).collect()
}

fn kinds(log: &RunLog) -> Vec<&str> {
    log.guard_events.iter().map(|e| e.kind.as_str()).collect()
}

/// The headline proof. An injected NaN at step 40 diverges a guarded
/// run; the guard rolls back to its step-40 snapshot, escalates to
/// `skip-ln-quant` (which cures the LN-quant-gated fault), and replays.
/// The result must be bitwise identical to an *unguarded oracle* that
/// applied the same intervention at step 40 via the policy engine —
/// prefix and suffix both — and the run must not read as diverged.
#[test]
fn recovered_run_matches_the_intervention_oracle_bitwise() {
    faults::arm(Fault::nan_loss("guardrec_a", 40));
    let sw = sweeper();
    let runner = sw.runner(BUNDLE).unwrap();

    let mut cfg = lm_cfg("guardrec_a", 60);
    cfg.guard = Some(GuardConfig { snapshot_every: 10, ..GuardConfig::default() });
    let guarded = runner.run(&cfg).unwrap().log;
    faults::clear_scope("guardrec_a");

    // Unguarded baseline (different name: the fault never fires).
    let baseline = runner.run(&lm_cfg("guardrec_base", 60)).unwrap().log;
    // Oracle: the same escalation applied by the Fig. 7 policy engine.
    let mut oracle_cfg = lm_cfg("guardrec_oracle", 60);
    oracle_cfg.policies = vec![Policy::at_step(40, Intervention::SkipLnQuant)];
    let oracle = runner.run(&oracle_cfg).unwrap().log;

    assert_eq!(guarded.recoveries.len(), 1);
    let r = &guarded.recoveries[0];
    assert_eq!((r.at_step, r.to_step, r.rung.as_str(), r.retry), (40, 40, "skip-ln-quant", 1));
    assert_eq!(kinds(&guarded), ["diverged", "rollback", "replay-done"]);
    assert!(!guarded.quarantined);
    assert_eq!(guarded.diverged_at, None, "a recovered run must not read as diverged");
    assert!(guarded.interventions.is_empty(), "guard rungs are not policy interventions");

    // Prefix (steps < 40): untouched by the recovery, bitwise = baseline.
    assert_eq!(guarded.rows.len(), 60, "the NaN row was dropped by the rollback");
    assert!(guarded.rows[..40].iter().all(|r| r.rung.is_none()));
    assert_eq!(
        RunLog::rows_jsonl(&guarded.rows[..40]),
        RunLog::rows_jsonl(&baseline.rows[..40]),
        "pre-divergence prefix must be bitwise identical to the unguarded baseline"
    );
    // Suffix (steps >= 40): rung-tagged, otherwise bitwise = oracle.
    assert!(guarded.rows[40..].iter().all(|r| r.rung == Some(1)));
    assert_eq!(
        RunLog::rows_jsonl(&strip_rungs(&guarded.rows[40..])),
        RunLog::rows_jsonl(&oracle.rows[40..]),
        "post-recovery suffix must be bitwise identical to the intervention oracle"
    );
    assert!(guarded.final_loss().is_finite());
}

/// Divergence at the very first step: the baseline snapshot (taken at
/// the first step seen, before anything ran) is the rollback target.
#[test]
fn divergence_at_step_zero_rolls_back_to_the_baseline_snapshot() {
    faults::arm(Fault::nan_loss("guardzero_a", 0));
    let sw = sweeper();
    let mut cfg = lm_cfg("guardzero_a", 12);
    cfg.guard = Some(GuardConfig { snapshot_every: 10, ..GuardConfig::default() });
    let log = sw.runner(BUNDLE).unwrap().run(&cfg).unwrap().log;
    faults::clear_scope("guardzero_a");

    assert_eq!(log.recoveries.len(), 1);
    let r = &log.recoveries[0];
    assert_eq!((r.at_step, r.to_step, r.rung.as_str()), (0, 0, "skip-ln-quant"));
    assert_eq!(log.rows.len(), 12);
    assert!(log.rows.iter().all(|r| r.rung == Some(1)), "every row is post-escalation");
    assert!(log.final_loss().is_finite());
}

/// A rung that does *not* cure the fault: the NaN re-fires during the
/// replay (loss faults are exact-step and never self-disarm), so the
/// guard must escalate again from the same snapshot — two recoveries,
/// then a clean finish under the rung that works.
#[test]
fn divergence_during_replay_escalates_a_second_rung() {
    faults::arm(Fault::nan_loss("guardreplay_a", 5));
    let sw = sweeper();
    let mut cfg = lm_cfg("guardreplay_a", 12);
    // forward-only leaves quant_ln set, so the injected LN-quant blowup
    // re-fires at step 5 of the replay; skip-ln-quant then cures it.
    cfg.guard = Some(GuardConfig {
        ladder: vec![Intervention::ForwardOnly, Intervention::SkipLnQuant],
        snapshot_every: 10,
        ..GuardConfig::default()
    });
    let log = sw.runner(BUNDLE).unwrap().run(&cfg).unwrap().log;
    faults::clear_scope("guardreplay_a");

    let recs: Vec<_> = log
        .recoveries
        .iter()
        .map(|r| (r.at_step, r.to_step, r.rung.as_str(), r.retry))
        .collect();
    assert_eq!(
        recs,
        [(5, 0, "forward-only", 1), (5, 0, "skip-ln-quant", 2)],
        "both recoveries restart from the step-0 baseline snapshot"
    );
    assert_eq!(kinds(&log), ["diverged", "rollback", "diverged", "rollback", "replay-done"]);
    assert!(!log.quarantined);
    assert!(log.final_loss().is_finite());
    assert_eq!(log.rows.len(), 12);
    assert!(log.rows.iter().all(|r| r.rung == Some(2)));
}

/// Ladder exhausted: a single rung that cannot cure the fault drives the
/// run to the quarantined terminal state — an `Ok` return with the NaN
/// rows retained (so `--require-finite` style gates still fail it), not
/// a panic or an `Err`.
#[test]
fn exhausted_ladder_quarantines_instead_of_erroring() {
    faults::arm(Fault::nan_loss("guardladd_a", 5));
    let sw = sweeper();
    let mut cfg = lm_cfg("guardladd_a", 12);
    cfg.guard = Some(GuardConfig {
        ladder: vec![Intervention::ForwardOnly],
        snapshot_every: 10,
        ..GuardConfig::default()
    });
    let log = sw.runner(BUNDLE).unwrap().run(&cfg).unwrap().log;
    faults::clear_scope("guardladd_a");

    assert!(log.quarantined);
    assert_eq!(log.recoveries.len(), 1, "the one rung was spent before quarantine");
    assert_eq!(kinds(&log), ["diverged", "rollback", "diverged", "quarantine"]);
    assert_eq!(log.rows.last().unwrap().step, 5, "the run stopped at the divergence");
    assert!(log.rows.last().unwrap().m.loss.is_nan(), "quarantined runs keep the NaN row");
    assert!(log.summary_json().to_string().contains("\"quarantined\":true"));
}

/// Retry budget exhausted mid-ladder. The first rung is an *identity*
/// escalation (the base fmt already has `quant_bwd` off, so forward-only
/// changes nothing), which also exercises the replay-bitwise assertion:
/// the replayed segment — including the NaN row — must reproduce the
/// dropped rows bit for bit, or the run errors.
#[test]
fn retry_budget_quarantines_and_identity_replay_is_bitwise_checked() {
    faults::arm(Fault::nan_loss("guardbudget_a", 5));
    let sw = sweeper();
    let mut cfg = lm_cfg("guardbudget_a", 12);
    cfg.fmt = Fmt { quant_bwd: false, ..cfg.fmt };
    cfg.guard = Some(GuardConfig {
        ladder: vec![Intervention::ForwardOnly, Intervention::BumpExponent],
        snapshot_every: 10,
        retry_budget: 1,
        ..GuardConfig::default()
    });
    // The identity replay re-fires the NaN at step 5 with bit-identical
    // metrics (asserted internally by Guard::check_replay), diverges
    // again, and the second recovery exceeds the budget of 1.
    let log = sw.runner(BUNDLE).unwrap().run(&cfg).unwrap().log;
    faults::clear_scope("guardbudget_a");

    assert!(log.quarantined);
    assert_eq!(log.recoveries.len(), 1);
    assert_eq!(log.recoveries[0].rung, "forward-only");
    assert_eq!(kinds(&log), ["diverged", "rollback", "diverged", "quarantine"]);
}

/// Regression for the segmented-run detector blind spot: a ≥κ× loss
/// spike at exactly the snapshot boundary of `run_with_snapshot` must
/// still be counted. (A fresh detector in the post-segment would have
/// `prev_loss = None` at the boundary and silently miss it.)
#[test]
fn spike_at_snapshot_boundary_is_detected() {
    faults::arm(Fault::spike_loss("guardsnap_a", 10));
    let sw = sweeper();
    let cfg = lm_cfg("guardsnap_a", 20);
    let (full, _snap) = sw.runner(BUNDLE).unwrap().run_with_snapshot(&cfg, 10).unwrap();
    faults::clear_scope("guardsnap_a");

    assert_eq!(full.log.spikes, 1, "boundary spike must survive the segment split");
    assert_eq!(full.log.rows.len(), 20, "pre + post rows merge seamlessly");
}

/// End-to-end `OnGradGrowth` trigger: an injected 1000× grad-norm spike
/// at step 10 pushes the detector's trailing growth ratio over the
/// threshold, so the policy fires at the *next* step boundary.
#[test]
fn grad_growth_policy_fires_end_to_end() {
    faults::arm(Fault::spike_loss("guardgrow_a", 10));
    let sw = sweeper();
    let mut cfg = lm_cfg("guardgrow_a", 15);
    cfg.policies = vec![Policy::on_grad_growth(100.0, Intervention::SkipLnQuant)];
    let log = sw.runner(BUNDLE).unwrap().run(&cfg).unwrap().log;
    faults::clear_scope("guardgrow_a");

    assert_eq!(
        log.interventions,
        vec![(11, "skip-ln-quant".to_string())],
        "the growth trigger fires at the first step boundary after the spike"
    );
    assert_eq!(log.spikes, 1);
}

/// Crash parity *through* a recovery: a worker killed mid-replay (via
/// the `guard.replay` fault point) leaves a lease behind; the reclaiming
/// worker resumes from the rollback-target checkpoint, re-derives the
/// identical recovery from the persisted detector + guard state, and
/// publishes a `done/` log **and flight recorder** byte-identical to an
/// uninterrupted guarded run's.
#[test]
fn worker_killed_mid_recovery_resumes_bitwise_identical() {
    let dir_g = tdir("kill_gold");
    let dir_f = tdir("kill_fault");
    // NaN at step 45 — off the checkpoint grid (every 10), so the
    // rollback lands at 40 and the replay spans steps 40..45, giving the
    // mid-replay kill a window to land in.
    faults::arm(Fault::nan_loss("guardkill_a", 45));
    let mut cfg = lm_cfg("guardkill_a", 60);
    cfg.guard = Some(GuardConfig::default()); // worker pins cadence to the grid
    let job = Job { bundle: BUNDLE.into(), cfg };
    let sw = sweeper();

    // Golden: uninterrupted single-worker guarded run.
    let golden = Spool::init(&dir_g).unwrap();
    golden.enqueue(&job).unwrap();
    let rep = run_worker(&sw, &golden, &{
        let mut w = WorkerConfig::new("guardkill_gw");
        w.checkpoint_every = 10;
        w.poll_ms = 20;
        w
    })
    .unwrap();
    assert_eq!(rep.completed, vec!["guardkill_a".to_string()]);
    let gold_log = std::fs::read(dir_g.join("done/guardkill_a.jsonl")).unwrap();
    let gold_rec = std::fs::read(dir_g.join("done/guardkill_a.guard.jsonl")).unwrap();
    assert!(
        String::from_utf8_lossy(&gold_rec).contains("\"kind\":\"rollback\""),
        "the published flight recorder must show the recovery"
    );

    // Faulted: kill the first worker while it replays step 42 (strictly
    // inside the 40..45 replay window), then let a second worker reclaim.
    faults::arm(
        Fault::new("guard.replay", FaultAction::Kill).with_scope("guardkill_kw0").at_step(42),
    );
    let faulted = Spool::init(&dir_f).unwrap();
    faulted.enqueue(&job).unwrap();
    let mut w0 = WorkerConfig::new("guardkill_kw0");
    w0.checkpoint_every = 10;
    w0.poll_ms = 20;
    let rep = run_worker(&sw, &faulted, &w0).unwrap();
    assert!(rep.killed, "the guard.replay fault must kill the worker mid-recovery");
    faults::clear_scope("guardkill_kw0");

    std::thread::sleep(std::time::Duration::from_millis(120));
    let mut w1 = WorkerConfig::new("guardkill_kw1");
    w1.checkpoint_every = 10;
    w1.lease_timeout_ms = 100;
    w1.poll_ms = 20;
    let rep = run_worker(&sw, &faulted, &w1).unwrap();
    faults::clear_scope("guardkill_a");
    assert_eq!(rep.reclaimed, vec!["guardkill_a".to_string()]);
    assert_eq!(rep.completed, vec!["guardkill_a".to_string()]);

    assert_eq!(
        std::fs::read(dir_f.join("done/guardkill_a.jsonl")).unwrap(),
        gold_log,
        "resumed-through-recovery rows must be bitwise identical"
    );
    assert_eq!(
        std::fs::read(dir_f.join("done/guardkill_a.guard.jsonl")).unwrap(),
        gold_rec,
        "the re-derived recovery must produce an identical flight recorder"
    );
    std::fs::remove_dir_all(&dir_g).ok();
    std::fs::remove_dir_all(&dir_f).ok();
}
