//! Cross-tier bitwise parity for the SIMD microkernel layer
//! (DESIGN.md §Exec, "Microkernels & dispatch").
//!
//! Every kernel tier (`scalar`, `panel`, `simd`) must be **bitwise
//! identical** on the packed codec, the quantized block GEMM (mixed
//! format pairs, strip/tile tails, zero blocks, subnormals, NaN/Inf),
//! the dense f32 GEMM, and — end to end — a multi-step fully-quantized
//! native LM training trajectory. The per-op parity lives in
//! `formats/kernel`'s unit tests; this suite proves the tiers compose
//! identically through the full pipeline.
//!
//! [`mxstab::formats::kernel::force_tier`] is process-global, so every
//! test here serializes on one mutex (and clears any stale override
//! after a poisoning panic).

use std::sync::{Mutex, MutexGuard};

use mxstab::data::{Corpus, CorpusConfig};
use mxstab::formats::dot::{encode, mx_dot};
use mxstab::formats::gemm::{gemm, gemm_f32, gemm_ref, PackedMatrix};
use mxstab::formats::kernel::{self, Tier};
use mxstab::formats::packed::{packed_qdq, PackedVec};
use mxstab::formats::quant::mx_qdq;
use mxstab::formats::spec::{hyper_idx, Fmt, FormatId, BLOCK_SIZE};
use mxstab::runtime::native::{LmConfig, LmModel, NativeState};
use mxstab::runtime::{Backend, Metrics, StepArgs};
use mxstab::util::rng::Xoshiro256;

static TIER_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    let g = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    kernel::force_tier(None); // clear any override a panicked test left
    g
}

fn with_tier<T>(t: Tier, f: impl FnOnce() -> T) -> T {
    kernel::force_tier(Some(t));
    let r = f();
    kernel::force_tier(None);
    r
}

/// Every tier that exists on this machine (simd only when an ISA does).
fn tiers() -> Vec<Tier> {
    let mut v = vec![Tier::Scalar, Tier::Panel];
    if kernel::simd_ops().is_some() {
        v.push(Tier::Simd);
    }
    v
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Adversarial inputs: normals, wide dynamic range, f32 subnormals,
/// all-zero blocks, ±inf, NaN, −0, and the §6.1 clamp cluster.
fn adversarial(rng: &mut Xoshiro256, blocks: usize) -> Vec<f32> {
    let mut x = Vec::with_capacity(blocks * BLOCK_SIZE);
    for b in 0..blocks {
        for i in 0..BLOCK_SIZE {
            x.push(match (b * 7 + i) % 10 {
                0 => rng.normal() as f32,
                1 => (rng.normal() as f32) * (2.0f32).powi((rng.below(60) as i32) - 30),
                2 => f32::from_bits(rng.below(1 << 23) as u32), // subnormal
                3 => 0.0,
                4 => -0.0,
                5 => f32::INFINITY,
                6 => f32::NEG_INFINITY,
                7 => f32::NAN,
                8 => 0.897, // clamp cluster
                _ => rng.normal() as f32 * 0.01,
            });
        }
    }
    // One guaranteed all-zero block.
    for v in x.iter_mut().take(BLOCK_SIZE) {
        *v = 0.0;
    }
    x
}

const MX: [FormatId; 4] = [FormatId::E4M3, FormatId::E5M2, FormatId::E2M3, FormatId::E3M2];

#[test]
fn codec_bitwise_identical_across_tiers() {
    let _g = lock();
    let mut rng = Xoshiro256::seed_from(17);
    for case in 0..8 {
        let x = adversarial(&mut rng, 6);
        for id in MX {
            // The scalar oracle is tier-independent ground truth.
            let (want, cw) = mx_qdq(&x, id, false);
            for t in tiers() {
                let (packed, got) = with_tier(t, || {
                    (PackedVec::encode(&x, id, false), packed_qdq(&x, id, false))
                });
                assert_eq!(
                    bits(&want),
                    bits(&got.0),
                    "{id:?} case {case} tier {}: qdq diverged",
                    t.name()
                );
                assert_eq!(cw, got.1, "{id:?} case {case} tier {}: clamp count", t.name());
                // Encoded bytes/scales must match across tiers too.
                let reference = with_tier(Tier::Scalar, || PackedVec::encode(&x, id, false));
                assert_eq!(packed.codes, reference.codes, "{id:?} tier {}", t.name());
                assert_eq!(packed.scales, reference.scales, "{id:?} tier {}", t.name());
                assert_eq!(packed.clamped, reference.clamped, "{id:?} tier {}", t.name());
            }
        }
    }
}

#[test]
fn quantized_gemm_bitwise_identical_across_tiers() {
    let _g = lock();
    let mut rng = Xoshiro256::seed_from(29);
    // Shapes crossing every tiling edge: single output, tile tails
    // (n % TILE_N != 0), sub-tile n, odd m, and a pool fan-out.
    for &(m, n, k) in
        &[(1usize, 1usize, 32usize), (2, 7, 64), (37, 33, 96), (5, 32, 32), (96, 64, 128)]
    {
        let a = adversarial(&mut rng, m * k / BLOCK_SIZE);
        let b = adversarial(&mut rng, n * k / BLOCK_SIZE);
        for (ida, idb) in [
            (FormatId::E4M3, FormatId::E4M3),
            (FormatId::E4M3, FormatId::E5M2),
            (FormatId::E5M2, FormatId::E2M3),
            (FormatId::E2M3, FormatId::E3M2),
        ] {
            // gemm_ref never dispatches through the tier tables: it is
            // the in-repo oracle (operands encoded under the scalar
            // tier so the whole reference path is tier-free).
            let mut reference = vec![0.0f32; m * n];
            with_tier(Tier::Scalar, || {
                let am = PackedMatrix::encode(&a, m, k, ida, false);
                let bm = PackedMatrix::encode(&b, n, k, idb, false);
                gemm_ref(&am, &bm, &mut reference);
            });
            for t in tiers() {
                let got = with_tier(t, || {
                    // Encode *and* multiply under the tier: the full
                    // pipeline must be bit-identical, not just the GEMM.
                    let am = PackedMatrix::encode(&a, m, k, ida, false);
                    let bm = PackedMatrix::encode(&b, n, k, idb, false);
                    let mut c = vec![0.0f32; m * n];
                    gemm(&am, &bm, &mut c);
                    c
                });
                assert_eq!(
                    bits(&reference),
                    bits(&got),
                    "{ida:?}x{idb:?} {m}x{n}x{k} tier {}",
                    t.name()
                );
            }
        }
    }
    // Spot-check the oracle itself on a small shape: gemm under every
    // tier equals the MxBlock scalar dot.
    let (m, n, k) = (3usize, 5usize, 64usize);
    let a: Vec<f32> = rng.normal_vec(m * k);
    let b: Vec<f32> = rng.normal_vec(n * k);
    let f = FormatId::E4M3.elem().unwrap();
    for t in tiers() {
        let c = with_tier(t, || {
            let am = PackedMatrix::encode(&a, m, k, FormatId::E4M3, false);
            let bm = PackedMatrix::encode(&b, n, k, FormatId::E4M3, false);
            let mut c = vec![0.0f32; m * n];
            gemm(&am, &bm, &mut c);
            c
        });
        for r in 0..m {
            let ea = encode(&a[r * k..(r + 1) * k], &f, 0);
            for j in 0..n {
                let eb = encode(&b[j * k..(j + 1) * k], &f, 0);
                let want = mx_dot(&ea, &eb);
                assert_eq!(
                    c[r * n + j].to_bits(),
                    want.to_bits(),
                    "tier {} C[{r},{j}] vs mx_dot",
                    t.name()
                );
            }
        }
    }
}

#[test]
fn dense_gemm_f32_bitwise_identical_across_tiers() {
    let _g = lock();
    let mut rng = Xoshiro256::seed_from(43);
    // Odd shapes: lane tails (n % dense_w != 0), strip tails, k of 1,
    // and a fan-out-sized matrix.
    for &(m, n, k) in &[(1usize, 3usize, 1usize), (4, 9, 7), (33, 17, 70), (128, 96, 64)] {
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(n * k);
        let reference = with_tier(Tier::Scalar, || {
            let mut c = vec![0.0f32; m * n];
            gemm_f32(&a, &b, m, n, k, &mut c);
            c
        });
        // The scalar tier must itself equal the naive f64 chain.
        for r in 0..m.min(2) {
            for j in 0..n.min(3) {
                let mut acc = 0.0f64;
                for x in 0..k {
                    acc += (a[r * k + x] as f64) * (b[j * k + x] as f64);
                }
                assert_eq!(reference[r * n + j].to_bits(), (acc as f32).to_bits());
            }
        }
        for t in tiers() {
            let got = with_tier(t, || {
                let mut c = vec![0.0f32; m * n];
                gemm_f32(&a, &b, m, n, k, &mut c);
                c
            });
            assert_eq!(bits(&reference), bits(&got), "{m}x{n}x{k} tier {}", t.name());
        }
    }
}

fn tiny_lm() -> LmModel {
    LmModel::new(LmConfig { layers: 2, d_model: 32, n_heads: 1, vocab: 64, ctx: 32, batch: 2 })
        .unwrap()
}

fn lm_args(m: &LmModel, corpus: &Corpus, fmt: Fmt, step: i32) -> StepArgs {
    let (b, l) = m.tokens_shape().unwrap();
    let mut hyper = vec![0.0f32; hyper_idx::HYPER_LEN];
    hyper[hyper_idx::LR] = 2e-3;
    let tokens = Some(corpus.batch(9, step as u64, b, l));
    StepArgs { tokens, fmt: fmt.to_vec(), hyper, seed: 9, step }
}

fn metric_bits(m: &Metrics) -> [u32; 9] {
    [
        m.loss.to_bits(),
        m.grad_norm.to_bits(),
        m.ln_frac_first.to_bits(),
        m.ln_frac_mean.to_bits(),
        m.act_frac_mean.to_bits(),
        m.update_norm.to_bits(),
        m.param_norm.to_bits(),
        m.eps_ratio.to_bits(),
        m.cosine.to_bits(),
    ]
}

/// Run `steps` fully-quantized LM training steps (last one paired, so
/// the fp32 reference pass + gradient-bias diagnostics are covered) and
/// return every per-step metric plus the final state snapshot.
fn lm_trajectory(m: &LmModel, corpus: &Corpus, steps: i32) -> (Vec<[u32; 9]>, Vec<Vec<f32>>) {
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let mut state: NativeState = m.init(5, 0.0, 1.0).unwrap();
    let mut mets = Vec::new();
    for step in 0..steps {
        let args = lm_args(m, corpus, fmt, step);
        let (s2, met) = if step == steps - 1 {
            m.paired_step(state, &args).unwrap()
        } else {
            m.step(state, &args).unwrap()
        };
        state = s2;
        mets.push(metric_bits(&met));
    }
    let snap = m.snapshot(&state).unwrap();
    (mets, snap)
}

#[test]
fn lm_trajectory_bitwise_identical_scalar_vs_simd() {
    let _g = lock();
    let m = tiny_lm();
    let corpus = Corpus::new(CorpusConfig { vocab: m.config().vocab, ..Default::default() });
    let steps = 4;
    let (met_scalar, snap_scalar) = with_tier(Tier::Scalar, || lm_trajectory(&m, &corpus, steps));
    for t in tiers() {
        if t == Tier::Scalar {
            continue;
        }
        let (met_t, snap_t) = with_tier(t, || lm_trajectory(&m, &corpus, steps));
        assert_eq!(met_scalar, met_t, "metrics diverged under tier {}", t.name());
        assert_eq!(snap_scalar.len(), snap_t.len());
        for (i, (a, b)) in snap_scalar.iter().zip(&snap_t).enumerate() {
            assert_eq!(
                bits(a),
                bits(b),
                "state tensor {i} diverged under tier {} after {steps} steps",
                t.name()
            );
        }
        // Held-out eval must agree bit-for-bit too.
        let toks = corpus.batch(mxstab::data::HELD_OUT_SEED, 0, 2, 33);
        let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3).to_vec();
        let state_s = with_tier(Tier::Scalar, || {
            let (_, s) = lm_trajectory(&m, &corpus, 1);
            m.restore(s).unwrap()
        });
        let ev_s = with_tier(Tier::Scalar, || m.eval(&state_s, &toks, &fmt).unwrap());
        let ev_t = with_tier(t, || m.eval(&state_s, &toks, &fmt).unwrap());
        assert_eq!(ev_s.to_bits(), ev_t.to_bits(), "eval diverged under tier {}", t.name());
    }
}

#[test]
fn scalar_tier_routes_gemm_to_reference_kernel() {
    let _g = lock();
    // Under the scalar tier, gemm() and gemm_ref() are the same code
    // path — the MXSTAB_KERNEL=scalar CI leg relies on this.
    let mut rng = Xoshiro256::seed_from(71);
    let (m, n, k) = (6usize, 10usize, 64usize);
    let a = rng.normal_vec(m * k);
    let b = rng.normal_vec(n * k);
    with_tier(Tier::Scalar, || {
        let am = PackedMatrix::encode(&a, m, k, FormatId::E4M3, false);
        let bm = PackedMatrix::encode(&b, n, k, FormatId::E4M3, false);
        let mut c1 = vec![0.0f32; m * n];
        let mut c2 = vec![0.0f32; m * n];
        gemm(&am, &bm, &mut c1);
        gemm_ref(&am, &bm, &mut c2);
        assert_eq!(bits(&c1), bits(&c2));
    });
}
