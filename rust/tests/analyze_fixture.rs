//! The analyzer must flag the committed bad fixture: one deliberate
//! violation per rule, each at the exact `file:line:col` the marker
//! sits on — and none of the NEGATIVE lines (rule keywords inside
//! comments, strings, and raw strings) may produce a diagnostic.

use mxstab::analyze::{analyze_source, Options};

const FIXTURE: &str = include_str!("../src/analyze/testdata/bad_fixture.rs");
const PATH: &str = "rust/src/analyze/testdata/bad_fixture.rs";

/// (line, col) of `token` on the line carrying `marker`, both 1-based.
fn line_col(marker: &str, token: &str) -> (u32, u32) {
    for (i, l) in FIXTURE.lines().enumerate() {
        if l.contains(marker) {
            let col = l.find(token).unwrap_or_else(|| {
                panic!("marker line {marker:?} does not contain {token:?}")
            });
            return ((i + 1) as u32, (col + 1) as u32);
        }
    }
    panic!("fixture has no line with marker {marker:?}");
}

#[test]
fn fixture_trips_every_rule_at_the_marked_position() {
    // --no-scope: no single real path is in-scope for all six rules at
    // once (no-fma wants formats/, the unwrap rule wants spool/worker/
    // fsio), so the fixture self-test disables path scoping.
    let out = analyze_source(PATH, FIXTURE, &Options { ignore_scope: true });

    let expected = [
        ("no-unordered-iter", line_col("VIOLATION[no-unordered-iter]", "HashMap")),
        ("no-fma", line_col("VIOLATION[no-fma]", "mul_add")),
        ("no-wallclock", line_col("VIOLATION[no-wallclock]", "Instant")),
        ("float-eq", line_col("VIOLATION[float-eq]", "==")),
        (
            "no-bare-unwrap-in-crash-path",
            line_col("VIOLATION[no-bare-unwrap-in-crash-path]", "unwrap"),
        ),
        ("unsafe-confinement", line_col("VIOLATION[unsafe-confinement]", "unsafe")),
    ];
    for (rule, (line, col)) in expected {
        assert!(
            out.violations
                .iter()
                .any(|d| d.rule == rule && d.line == line && d.col == col),
            "rule {rule} did not fire at {line}:{col}; got:\n{}",
            out.violations
                .iter()
                .map(|d| d.render())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    // The unconfined unsafe block also lacks a SAFETY comment: two
    // diagnostics on the same token.
    let unsafe_diags = out
        .violations
        .iter()
        .filter(|d| d.rule == "unsafe-confinement")
        .count();
    assert_eq!(unsafe_diags, 2, "unconfined + missing-SAFETY");

    // Exactly the planted violations, nothing more: 5 single-diagnostic
    // rules + the double-diagnostic unsafe site.
    assert_eq!(out.violations.len(), 7, "unexpected extra diagnostics");

    // NEGATIVE lines (keywords in comments / strings / raw strings)
    // must stay silent.
    let negative_lines: Vec<u32> = FIXTURE
        .lines()
        .enumerate()
        .filter(|(_, l)| l.contains("NEGATIVE"))
        .map(|(i, _)| (i + 1) as u32)
        .collect();
    assert!(negative_lines.len() >= 3, "fixture lost its NEGATIVE controls");
    for d in &out.violations {
        assert!(
            !negative_lines.contains(&d.line),
            "false positive on a NEGATIVE line: {}",
            d.render()
        );
    }

    // The demo pragma suppresses its wallclock read AND is counted as
    // used — the self-test covers the allow-consumption path too.
    assert!(
        out.unused_allows.is_empty(),
        "the fixture's allow pragma must be consumed: {:?}",
        out.unused_allows
            .iter()
            .map(|d| d.render())
            .collect::<Vec<_>>()
    );
    let wallclock = out
        .violations
        .iter()
        .filter(|d| d.rule == "no-wallclock")
        .count();
    assert_eq!(wallclock, 1, "the pragma'd Instant::now must be suppressed");
}
