//! Integration tests over the compiled artifacts (require `make artifacts`,
//! at least the `quick` set). Each test skips with a notice when the
//! artifacts are absent so `cargo test` stays usable pre-build.

use std::path::{Path, PathBuf};
use std::sync::{Arc, OnceLock};

use mxstab::formats::{mx_qdq, packed_qdq, Fmt, FormatId};
use mxstab::runtime::{Bundle, Quantizer, Session, State, StepArgs};
use mxstab::util::rng::Xoshiro256;

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn session() -> Arc<Session> {
    static SESSION: OnceLock<Arc<Session>> = OnceLock::new();
    SESSION.get_or_init(|| Session::cpu().expect("PJRT CPU client")).clone()
}

fn have(name: &str) -> Option<PathBuf> {
    let dir = artifacts_root().join(name);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifact {name} not built (run `make artifacts`)");
        None
    }
}

fn proxy_dir() -> Option<PathBuf> {
    have("proxy_gelu_ln_L2_D128").or_else(|| have("proxy_gelu_ln_L2_D256"))
}

fn default_args(fmt: Fmt, lr: f32, step: i32) -> StepArgs {
    let mut hyper = vec![0.0f32; 4];
    hyper[0] = lr;
    hyper[3] = 1e-3; // label noise
    StepArgs { tokens: None, fmt: fmt.to_vec(), hyper, seed: 0, step }
}

#[test]
fn quantizer_artifact_matches_rust_mirror_bitexact() {
    let Some(dir) = have("quantizer") else { return };
    let q = Quantizer::load(session(), &dir).unwrap();
    let mut rng = Xoshiro256::seed_from(99);
    let n = q.rows * q.cols;
    // Mixed distribution incl. tight clusters (the clamping-prone case).
    let mut x = rng.normal_vec(n);
    for v in x.iter_mut().skip(n / 2) {
        *v = ((rng.normal() * 0.01).exp()) as f32;
    }
    for id in FormatId::ALL {
        let (y_hlo, frac_hlo) = q.qdq(&x, id as u8 as f32, 0.0).unwrap();
        // The packed engine is the production emulation path; hold it to
        // the golden artifact directly, and to the scalar oracle bitwise.
        let (y_rs, clamped) = packed_qdq(&x, id, false);
        let (y_scalar, clamped_scalar) = mx_qdq(&x, id, false);
        assert_eq!(y_rs, y_scalar, "format {id:?}: packed vs scalar mismatch");
        assert_eq!(clamped, clamped_scalar, "format {id:?}: clamp count");
        assert_eq!(y_hlo, y_rs, "format {id:?}: HLO vs rust mismatch");
        let frac_rs = clamped as f32 / n as f32;
        assert!(
            (frac_hlo - frac_rs).abs() < 1e-6,
            "format {id:?}: last-bin frac {frac_hlo} vs {frac_rs}"
        );
    }
}

#[test]
fn quantizer_scale_bump_reduces_clamping() {
    let Some(dir) = have("quantizer") else { return };
    let q = Quantizer::load(session(), &dir).unwrap();
    let mut rng = Xoshiro256::seed_from(5);
    // Tight log-normal cluster around 0.9: mantissa-of-max ≈ 1.8 → the
    // §6.1 clamping regime (a cluster around 1.0 would *not* clamp, since
    // the block max's mantissa would be ≈1.0).
    let x: Vec<f32> = (0..q.rows * q.cols)
        .map(|_| (0.9 * (rng.normal() * 0.01).exp()) as f32)
        .collect();
    let (_, f0) = q.qdq(&x, FormatId::E4M3 as u8 as f32, 0.0).unwrap();
    let (_, f1) = q.qdq(&x, FormatId::E4M3 as u8 as f32, 1.0).unwrap();
    assert!(f0 > 0.0, "cluster should clamp without bump (got {f0})");
    assert_eq!(f1, 0.0, "bump should clear the last bin");
}

#[test]
fn proxy_init_is_deterministic() {
    let Some(dir) = proxy_dir() else { return };
    let b = Bundle::load(session(), &dir).unwrap();
    let s1 = b.init(42, 0.0, 1.0).unwrap();
    let s2 = b.init(42, 0.0, 1.0).unwrap();
    let s3 = b.init(43, 0.0, 1.0).unwrap();
    assert_eq!(s1.0.len(), b.manifest.state.len());
    let a = s1.tensor_f32(0).unwrap();
    assert_eq!(a, s2.tensor_f32(0).unwrap());
    assert_ne!(a, s3.tensor_f32(0).unwrap());
    // Kaiming-uniform bound: |w| ≤ 1/sqrt(fan_in) = 1/sqrt(128).
    let bound = 1.0 / (128f32).sqrt() + 1e-6;
    assert!(a.iter().all(|v| v.abs() <= bound));
    // Layernorm gammas init to 1.
    let ln_idx = b
        .manifest
        .state
        .iter()
        .position(|t| t.name == "p_ln")
        .expect("proxy state has p_ln");
    assert!(s1.tensor_f32(ln_idx).unwrap().iter().all(|&v| v == 1.0));
}

#[test]
fn proxy_training_loss_decreases_and_is_deterministic() {
    let Some(dir) = proxy_dir() else { return };
    let b = Bundle::load(session(), &dir).unwrap();
    let fmt = Fmt::fp32();
    let mut state = b.init(0, 0.0, 1.0).unwrap();
    let mut first = None;
    let mut last = 0.0;
    for t in 0..30 {
        let (s2, met) = b.step(state, &default_args(fmt, 1e-3, t)).unwrap();
        state = s2;
        if t == 0 {
            first = Some(met.loss);
        }
        last = met.loss;
        assert!(met.is_finite(), "step {t}");
    }
    assert!(last < first.unwrap() * 0.8, "loss {last} vs {first:?}");

    // Re-run: identical trajectory (deterministic data + init + kernels).
    let mut state = b.init(0, 0.0, 1.0).unwrap();
    let mut last2 = 0.0;
    for t in 0..30 {
        let (s2, met) = b.step(state, &default_args(fmt, 1e-3, t)).unwrap();
        state = s2;
        last2 = met.loss;
    }
    assert_eq!(last, last2);
}

#[test]
fn proxy_mx_format_changes_trajectory_but_stays_close_early() {
    let Some(dir) = proxy_dir() else { return };
    let b = Bundle::load(session(), &dir).unwrap();
    let run = |fmt: Fmt| -> Vec<f32> {
        let mut state = b.init(0, 0.0, 1.0).unwrap();
        let mut losses = vec![];
        for t in 0..20 {
            let (s2, met) = b.step(state, &default_args(fmt, 5e-4, t)).unwrap();
            state = s2;
            losses.push(met.loss);
        }
        losses
    };
    let fp = run(Fmt::fp32());
    let mx = run(Fmt::full(FormatId::E4M3, FormatId::E4M3));
    assert_ne!(fp, mx, "quantization must alter the trajectory");
    let rel = (fp.last().unwrap() - mx.last().unwrap()).abs() / fp.last().unwrap();
    assert!(rel < 0.5, "E4M3 should track FP32 early in training (rel={rel})");
}

#[test]
fn paired_step_reports_gradient_bias() {
    let Some(dir) = proxy_dir() else { return };
    let b = Bundle::load(session(), &dir).unwrap();
    if !b.has_paired() {
        eprintln!("SKIP: no paired fn");
        return;
    }
    let state = b.init(0, 0.0, 1.0).unwrap();
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let (_, met) = b.paired_step(state, &default_args(fmt, 5e-4, 0)).unwrap();
    assert!(met.eps_ratio > 0.0 && met.eps_ratio < 1.0, "eps_ratio {}", met.eps_ratio);
    assert!(met.cosine > 0.9, "cosine {}", met.cosine);

    // In FP32 the paired gradient must match itself exactly.
    let state = b.init(0, 0.0, 1.0).unwrap();
    let (_, met) = b.paired_step(state, &default_args(Fmt::fp32(), 5e-4, 0)).unwrap();
    assert_eq!(met.eps_ratio, 0.0);
    assert!((met.cosine - 1.0).abs() < 1e-5);
}

#[test]
fn intervention_fmt_swap_mid_run_keeps_state() {
    let Some(dir) = proxy_dir() else { return };
    let b = Bundle::load(session(), &dir).unwrap();
    let mx = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    // Train 10 steps MX, then switch to fp32 — loss stays finite and keeps
    // improving (the Fig. 7 mechanism: fmt is a pure runtime input).
    let mut state = b.init(1, 0.0, 1.0).unwrap();
    let mut loss10 = f32::NAN;
    for t in 0..10 {
        let (s2, met) = b.step(state, &default_args(mx, 1e-3, t)).unwrap();
        state = s2;
        loss10 = met.loss;
    }
    let mut last = f32::NAN;
    for t in 10..25 {
        let (s2, met) = b.step(state, &default_args(Fmt::fp32(), 1e-3, t)).unwrap();
        state = s2;
        last = met.loss;
    }
    assert!(last.is_finite() && last < loss10, "post-intervention {last} vs {loss10}");
}

#[test]
fn pallas_bundle_matches_jnp_bundle_bitexact() {
    // The pallas-integrated proxy and the jnp proxy share shapes + seed →
    // identical trajectories if (and only if) L1 ≡ ref quantizer.
    let (Some(dir_jnp), Some(dir_pal)) = (
        have("proxy_gelu_ln_L2_D128"),
        have("proxy_gelu_ln_L2_D128_pallas"),
    ) else {
        return;
    };
    let bj = Bundle::load(session(), &dir_jnp).unwrap();
    let bp = Bundle::load(session(), &dir_pal).unwrap();
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let mut sj = bj.init(3, 0.0, 1.0).unwrap();
    let mut sp = bp.init(3, 0.0, 1.0).unwrap();
    for t in 0..5 {
        let (s2, mj) = bj.step(sj, &default_args(fmt, 5e-4, t)).unwrap();
        sj = s2;
        let (s2, mp) = bp.step(sp, &default_args(fmt, 5e-4, t)).unwrap();
        sp = s2;
        assert_eq!(mj.loss, mp.loss, "step {t}: pallas and jnp paths diverge");
    }
    let _ = (sj, sp);
}

#[test]
fn lm_bundle_trains_on_synthetic_corpus() {
    let Some(dir) = have("lm_n1_v256_c64_b8") else { return };
    let b = Bundle::load(session(), &dir).unwrap();
    let (batch, len) = b.tokens_shape().unwrap();
    let corpus = mxstab::data::Corpus::new(mxstab::data::CorpusConfig {
        vocab: 256,
        ..Default::default()
    });
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let mut hyper = vec![0.0f32; 4];
    hyper[0] = 1e-3;
    let mut state = b.init(0, 0.0, 1.0).unwrap();
    let mut first = None;
    let mut last = 0.0;
    for t in 0..20 {
        let args = StepArgs {
            tokens: Some(corpus.batch(0, t as u64, batch, len)),
            fmt: fmt.to_vec(),
            hyper: hyper.clone(),
            seed: 0,
            step: t as i32,
        };
        let (s2, met) = b.step(state, &args).unwrap();
        state = s2;
        if t == 0 {
            first = Some(met.loss);
            // Initial loss ≈ ln(vocab) for a fresh LM.
            assert!((met.loss - (256f32).ln()).abs() < 0.7, "init loss {}", met.loss);
        }
        last = met.loss;
    }
    assert!(last < first.unwrap() - 0.5, "LM loss should fall: {first:?} → {last}");

    // Eval entry point returns a finite loss on held-out data.
    let val = b
        .eval(&state, &corpus.batch(999, 0, batch, len), &fmt.to_vec())
        .unwrap();
    assert!(val.is_finite() && val > 0.0 && val < 8.0, "val loss {val}");
}

#[test]
fn state_clone_is_deep() {
    let Some(dir) = proxy_dir() else { return };
    let b = Bundle::load(session(), &dir).unwrap();
    let state = b.init(0, 0.0, 1.0).unwrap();
    let snap: State = state.clone_state().unwrap();
    // Step the original; the snapshot must not change.
    let before = snap.tensor_f32(0).unwrap();
    let (_state2, _) = b
        .step(state, &default_args(Fmt::fp32(), 1e-3, 0))
        .unwrap();
    assert_eq!(snap.tensor_f32(0).unwrap(), before);
}

#[test]
fn list_bundles_finds_quick_set() {
    let root = artifacts_root();
    if !root.join("index.json").exists() {
        eprintln!("SKIP: no artifacts index");
        return;
    }
    let names = mxstab::runtime::list_bundles(Path::new(&root)).unwrap();
    assert!(names.iter().any(|n| n == "quantizer"));
}
