//! `.mxc` container integration suite (DESIGN.md §Container): the
//! bitwise-parity bar for mmap'd weight loading, the zero-re-encode
//! startup guarantee, hostile-container rejection with typed errors
//! before any decode, and torn-write fault injection on `mxstab pack`.

use std::path::PathBuf;
use std::sync::Arc;

use mxstab::coordinator::{RunConfig, Runner};
use mxstab::data::{Corpus, CorpusConfig};
use mxstab::formats::container::{self, MxcError, MxcFile, SiteIn, TensorIn, ALIGN};
use mxstab::formats::gemm::PackedMatrix;
use mxstab::formats::spec::{hyper_idx, Fmt, FormatId, BLOCK_SIZE};
use mxstab::runtime::native::cache::{CachedOp, Class, Site, Stage};
use mxstab::runtime::native::{NativeEngine, NativeModel};
use mxstab::runtime::{pack_to_container, Backend, Engine, StepArgs};
use mxstab::util::faults::{self, Fault, FaultAction};
use mxstab::util::mmap::Mapping;
use mxstab::util::rng::Xoshiro256;

const MODEL: &str = "lm_L1_D32_H1_T32_V64";

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mxstab-ct-{}-{tag}.mxc", std::process::id()))
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn lm_runner() -> Runner<NativeModel> {
    let engine = NativeEngine::with_batch(2).unwrap();
    let model = engine.load(MODEL).unwrap();
    let corpus = Arc::new(Corpus::new(CorpusConfig { vocab: 64, ..Default::default() }));
    Runner::new(model, Some(corpus))
}

/// Pack `MODEL`'s seed-`seed` init into a container at `path`.
fn pack_fresh(runner: &Runner<NativeModel>, fmt: &Fmt, seed: i32, path: &PathBuf) {
    let backend = runner.backend.as_ref();
    let state = backend.init(seed, 0.0, 1.0).unwrap();
    let tensors = backend.snapshot(&state).unwrap();
    pack_to_container(backend, &tensors, fmt, path).unwrap();
}

/// The parity bar is absolute: a trajectory started from `.mxc` weights
/// (mmap'd AND heap-loaded) must be bitwise identical to one started
/// from a fresh seeded init — exercised for byte-code (E4M3) and
/// nibble-packed (E2M1) site storage.
#[test]
fn trajectory_from_container_is_bitwise_identical_to_fresh_init() {
    for (fmt, tag) in [
        (Fmt::full(FormatId::E4M3, FormatId::E4M3), "parity-e4m3"),
        (Fmt::full(FormatId::E2M1, FormatId::E2M1), "parity-e2m1"),
    ] {
        let runner = lm_runner();
        let mut cfg = RunConfig::new("parity", fmt, 1e-2, 4);
        cfg.seed = 11;

        let fresh = runner.run(&cfg).unwrap();
        let fresh_state = fresh.final_state.as_ref().unwrap();

        let path = tmp(tag);
        pack_fresh(&runner, &fmt, cfg.seed, &path);

        // A: via RunConfig.weights — the mmap fast path.
        let mut via_weights = cfg.clone();
        via_weights.weights = Some(path.to_string_lossy().into_owned());
        let mapped = runner.run(&via_weights).unwrap();

        // B: explicit heap load (the no-mmap platform fallback).
        let heap_mxc = MxcFile::open_heap(&path).unwrap();
        assert!(!heap_mxc.is_mmap());
        let heap_state = runner.backend.load_weights(&heap_mxc).unwrap();
        let heaped = runner.run_from(&cfg, heap_state, 0).unwrap();

        for (out, label) in [(&mapped, "mmap"), (&heaped, "heap")] {
            assert_eq!(out.log.rows.len(), fresh.log.rows.len(), "{tag} {label}: rows");
            for (a, b) in out.log.rows.iter().zip(&fresh.log.rows) {
                assert_eq!(a.step, b.step, "{tag} {label}");
                let s = a.step;
                assert_eq!(a.m.loss.to_bits(), b.m.loss.to_bits(), "{tag} {label} step {s}");
                assert_eq!(
                    a.m.grad_norm.to_bits(),
                    b.m.grad_norm.to_bits(),
                    "{tag} {label} step {s}"
                );
            }
            let st = out.final_state.as_ref().unwrap();
            for (a, b) in st.tensors.iter().zip(&fresh_state.tensors) {
                assert_eq!(bits(a), bits(b), "{tag} {label}: final state diverged");
            }
        }
        std::fs::remove_file(&path).ok();
    }
}

/// Loading from a container must perform zero f32 re-encodes at startup:
/// the load itself touches no cache counters, every forward weight site
/// is pre-seeded with the container operand under the exact runtime key,
/// and the first step skips at least one encode per site versus a fresh
/// init — at bitwise-identical results.
#[test]
fn container_load_seeds_every_site_and_skips_reencode() {
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let runner = lm_runner();
    let backend = runner.backend.as_ref();
    let path = tmp("seed");
    pack_fresh(&runner, &fmt, 5, &path);

    let mxc = MxcFile::open(&path).unwrap();
    mxc.verify().unwrap();
    let sites = backend.pack_sites();
    assert!(!sites.is_empty());
    assert_eq!(mxc.meta().sites.len(), sites.len(), "every pack site stored");

    // O(header) + map: the load itself does no cache work at all.
    let seeded = backend.load_weights(&mxc).unwrap();
    assert_eq!(seeded.exec.stats(), (0, 0), "load must not touch hit/miss counters");
    let fresh = backend.init(5, 0.0, 1.0).unwrap();
    for (a, b) in seeded.tensors.iter().zip(&fresh.tensors) {
        assert_eq!(bits(a), bits(b), "restored masters must match the packed init");
    }

    // Every forward weight site is resident: peeking the exact runtime
    // key returns the container operand, bitwise equal to site_matrix.
    let probe = backend.load_weights(&mxc).unwrap();
    for (i, sm) in mxc.meta().sites.iter().enumerate() {
        let key = (
            Site::new(sm.tensor, sm.layer),
            Stage::FwdW,
            sm.fmt as u8,
            sm.bump,
            sm.geom.key_byte(),
        );
        let hit = probe
            .exec
            .peek(Class::Param, key)
            .unwrap_or_else(|| panic!("site {i} ({}) not seeded", sm.name));
        match hit {
            CachedOp::Packed(p) => {
                let want = mxc.site_matrix(i);
                assert_eq!(p.rows, want.rows, "{}", sm.name);
                assert_eq!(p.cols, want.cols, "{}", sm.name);
                assert_eq!(p.data, want.data, "seeded operand differs for {}", sm.name);
            }
            CachedOp::Dense(_) => panic!("weight site {} seeded as dense", sm.name),
        }
    }

    // One identical training step each: bitwise-equal results, and the
    // seeded run serves every forward weight from the container (one
    // peek hit per site, at least one fewer encode per site).
    let corpus = runner.corpus.as_ref().unwrap();
    let (bt, len) = backend.tokens_shape().unwrap();
    let mut hyper = vec![0.0f32; hyper_idx::HYPER_LEN];
    hyper[hyper_idx::LR] = 1e-2;
    let args = StepArgs {
        tokens: Some(corpus.batch(5, 0, bt, len)),
        fmt: fmt.to_vec(),
        hyper,
        seed: 5,
        step: 0,
    };
    let (s1, m1) = backend.step(seeded, &args).unwrap();
    let (s2, m2) = backend.step(fresh, &args).unwrap();
    assert_eq!(m1.loss.to_bits(), m2.loss.to_bits());
    assert_eq!(m1.grad_norm.to_bits(), m2.grad_norm.to_bits());
    for (a, b) in s1.tensors.iter().zip(&s2.tensors) {
        assert_eq!(bits(a), bits(b), "post-step state diverged");
    }
    let (seeded_hits, seeded_misses) = s1.exec.stats();
    let (_, fresh_misses) = s2.exec.stats();
    let n_sites = sites.len() as u64;
    assert!(
        seeded_hits >= n_sites,
        "every site must peek-hit its seeded operand ({seeded_hits} hits, {n_sites} sites)"
    );
    assert!(
        seeded_misses + n_sites <= fresh_misses,
        "seeded run must skip >= one encode per site ({seeded_misses} vs {fresh_misses})"
    );
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// Hostile containers: byte surgery on a valid file, every rejection typed
// and raised before any decode of the offending bytes.
// ---------------------------------------------------------------------------

/// A small valid container (one 32-f32 tensor, one e4m3 site) as raw
/// file bytes. Data-region layout (offsets relative to the region):
/// tensor at 0 (128 bytes), codes at 128 (256 bytes), scales at 384
/// (16 bytes), padded to 448.
fn valid_container_bytes(tag: &str) -> Vec<u8> {
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let mut rng = Xoshiro256::seed_from(3);
    let (n, k) = (4, 2 * BLOCK_SIZE);
    let wt = rng.normal_vec(n * k);
    let mat = PackedMatrix::encode_geom(&wt, n, k, fmt.w_fwd, fmt.scale_bump, fmt.geom);
    let tdata: Vec<f32> = (0..32).map(|i| i as f32 * 0.5 - 8.0).collect();
    let path = tmp(tag);
    container::write(
        &path,
        "hostile_workload",
        &fmt,
        &[TensorIn { name: "p_w", shape: vec![32], data: &tdata }],
        &[SiteIn { name: "w".into(), tensor: 0, layer: 0, mat: &mat }],
    )
    .unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    bytes
}

fn open_bytes(bytes: Vec<u8>) -> Result<MxcFile, MxcError> {
    MxcFile::from_mapping(Arc::new(Mapping::from_vec(bytes)))
}

/// Absolute file offset of the data region: `align64(16 + meta_len)`.
fn data_start(bytes: &[u8]) -> usize {
    let meta_len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    (16 + meta_len).div_ceil(ALIGN) * ALIGN
}

/// Same-length in-place metadata edit (keeps `meta_len` and the data
/// region byte-exact, so the mutation under test is the only change).
fn patch_meta(bytes: &mut [u8], from: &str, to: &str) {
    assert_eq!(from.len(), to.len(), "patch must preserve length");
    let hay = String::from_utf8_lossy(bytes).into_owned();
    let at = hay.find(from).unwrap_or_else(|| panic!("metadata lacks {from:?}"));
    bytes[at..at + to.len()].copy_from_slice(to.as_bytes());
}

#[test]
fn hostile_containers_are_rejected_with_typed_errors() {
    let good = valid_container_bytes("hostile");
    let parsed = open_bytes(good.clone()).expect("baseline must open");

    // Bad magic.
    let mut b = good.clone();
    b[0] = b'Z';
    match open_bytes(b) {
        Err(MxcError::BadMagic(m)) => assert_eq!(m[0], b'Z'),
        other => panic!("expected BadMagic, got {other:?}"),
    }

    // Unsupported version.
    let mut b = good.clone();
    b[4] = 9;
    assert!(matches!(open_bytes(b), Err(MxcError::BadVersion(9))));

    // Header truncation.
    assert!(matches!(open_bytes(good[..10].to_vec()), Err(MxcError::Truncated { .. })));

    // Data-region truncation: cutting ALIGN bytes off the tail removes
    // the trailing padding (< ALIGN) plus at least one byte of the last
    // section, so its bound check fails structurally at open.
    let b = good[..good.len() - ALIGN].to_vec();
    assert!(matches!(open_bytes(b), Err(MxcError::Truncated { .. })));

    // Misaligned section offset (the site codes live at 128).
    let mut b = good.clone();
    patch_meta(&mut b, "\"offset\":128", "\"offset\":129");
    assert!(matches!(open_bytes(b), Err(MxcError::Misaligned { offset: 129, .. })));

    // Format tag / geometry disagreement: an unsupported block size...
    let mut b = good.clone();
    patch_meta(&mut b, "\"block_size\":32", "\"block_size\":33");
    assert!(matches!(open_bytes(b), Err(MxcError::FmtGeometry(_))));

    // ...and a site element format contradicting the container run fmt.
    let mut b = good.clone();
    patch_meta(&mut b, "\"fmt\":\"e4m3\"", "\"fmt\":\"e5m2\"");
    assert!(matches!(open_bytes(b), Err(MxcError::FmtGeometry(_))));

    // Corrupted site codes: open stays O(header)-clean (checksums are
    // lazy by design), verify() catches the flip.
    let codes_at = data_start(&good) + parsed.meta().sites[0].codes.offset;
    let mut b = good.clone();
    b[codes_at] ^= 0xff;
    let f = open_bytes(b).expect("structure is intact");
    match f.verify() {
        Err(MxcError::Checksum { section, .. }) => assert!(section.contains("codes"), "{section}"),
        other => panic!("expected Checksum, got {other:?}"),
    }

    // Corrupted tensor bytes: caught by the decode-time checksum.
    let tensor_at = data_start(&good) + parsed.meta().tensors[0].section.offset;
    let mut b = good.clone();
    b[tensor_at] ^= 0x01;
    let f = open_bytes(b).expect("open never reads tensor bytes");
    assert!(matches!(f.tensor_f32(0), Err(MxcError::Checksum { .. })));
}

/// A torn `pack` write (crash mid-write) must leave a file that is
/// rejected at open — the packing path shares `write_atomic`'s fault
/// point, scoped by destination path.
#[test]
fn torn_pack_write_is_detected_at_open() {
    let fmt = Fmt::full(FormatId::E4M3, FormatId::E4M3);
    let mut rng = Xoshiro256::seed_from(7);
    let (n, k) = (4, BLOCK_SIZE);
    let wt = rng.normal_vec(n * k);
    let mat = PackedMatrix::encode_geom(&wt, n, k, fmt.w_fwd, fmt.scale_bump, fmt.geom);
    let site = || SiteIn { name: "w".into(), tensor: 0, layer: 0, mat: &mat };
    let path = tmp("torn");
    let scope = path.file_name().unwrap().to_str().unwrap().to_string();

    faults::arm(Fault::new("fsio.write", FaultAction::TornWrite { keep: 40 }).with_scope(&scope));
    let err = container::write(&path, "torn_workload", &fmt, &[], &[site()]).unwrap_err();
    faults::clear_scope(&scope);
    assert!(matches!(err, MxcError::Io(ref m) if m.contains("torn")), "{err}");

    // 40 bytes: valid magic + version, but the metadata is cut short —
    // typed rejection, no decode.
    assert!(matches!(MxcFile::open(&path), Err(MxcError::Truncated { .. })));

    // The fault disarmed after one hit: the retry lands a good file.
    container::write(&path, "torn_workload", &fmt, &[], &[site()]).unwrap();
    let f = MxcFile::open(&path).unwrap();
    f.verify().unwrap();
    assert_eq!(f.meta().workload, "torn_workload");
    std::fs::remove_file(&path).ok();
}
