"""Numeric-format constants and the runtime `fmt` tensor layout.

This module is the single source of truth for the element-format constants
(OCP MX spec) and for the layout of the two small runtime configuration
vectors (`fmt`, `hyper`) that the rust coordinator feeds into every compiled
step function.  The rust mirror lives in ``rust/src/formats/spec.rs`` and is
cross-checked by golden tests.

Element formats (OCP Microscaling spec v1.0):

==========  =====  =====  ======  ==========  =============
format      ebits  mbits  e_max   max_norm    emin (normal)
==========  =====  =====  ======  ==========  =============
FP8  E4M3   4      3      8       448         -6
FP8  E5M2   5      2      15      57344       -14
FP6  E2M3   2      3      2       7.5         0
FP6  E3M2   3      2      4       28          -2
FP4  E2M1   2      1      2       6.0         0
INT4        1      2      1       3.5         1
==========  =====  =====  ======  ==========  =============

``e_max`` is the exponent of the largest *normal* value — the quantity the
shared block scale is shifted by in Algorithm 1 of the paper.  ``emin`` is
the exponent of the smallest normal value (``2 - 2**(ebits-1)`` with the
IEEE-style bias the OCP spec uses); below it the grid continues with
subnormals at a fixed step of ``2**(emin - mbits)``.
"""

from __future__ import annotations

# ---------------------------------------------------------------------------
# Format ids (values of fmt[W_FMT_FWD] etc.; must match rust formats/spec.rs)
# ---------------------------------------------------------------------------
FP32 = 0  # passthrough (no quantization)
BF16 = 1  # plain bfloat16 round-to-nearest-even cast, no block scale
E4M3 = 2  # MXFP8
E5M2 = 3  # MXFP8
E2M3 = 4  # MXFP6
E3M2 = 5  # MXFP6
E2M1 = 6  # MXFP4 (sub-byte: two codes per storage byte on the rust side)
INT4 = 7  # INT4-style fixed-point-per-block (sub-byte, 1 exponent bit)

FORMAT_NAMES = {
    FP32: "fp32",
    BF16: "bf16",
    E4M3: "e4m3",
    E5M2: "e5m2",
    E2M3: "e2m3",
    E3M2: "e3m2",
    E2M1: "e2m1",
    INT4: "int4",
}
FORMAT_IDS = {v: k for k, v in FORMAT_NAMES.items()}

# (ebits, mbits, e_max, max_norm, emin_normal) per MX element format.
MX_CONSTANTS = {
    E4M3: (4, 3, 8, 448.0, -6),
    E5M2: (5, 2, 15, 57344.0, -14),
    E2M3: (2, 3, 2, 7.5, 0),
    E3M2: (3, 2, 4, 28.0, -2),
    E2M1: (2, 1, 2, 6.0, 0),
    INT4: (1, 2, 1, 3.5, 1),
}

BLOCK_SIZE = 32  # hardware MX block size (k in Algorithm 1)
BLOCK_SIZES = (16, 32, 64)  # generalized geometries the runtime accepts
TWO_LEVEL_SCALE_MAX = 448.0  # NVFP4 two-level: per-block scales cap at E4M3 max

# ---------------------------------------------------------------------------
# Runtime `fmt` vector layout: f32[FMT_LEN], one per training step call.
# ---------------------------------------------------------------------------
W_FMT_FWD = 0   # weight operand format in forward GEMMs (format id)
A_FMT_FWD = 1   # activation operand format in forward GEMMs
G_FMT_BWD = 2   # gradient operand format in backward GEMMs
W_FMT_BWD = 3   # weight operand format in backward GEMMs
A_FMT_BWD = 4   # activation operand format in backward GEMMs
QUANT_FWD = 5   # 0/1: quantize forward GEMM operands at all
QUANT_BWD = 6   # 0/1: quantize backward GEMM operands at all
QUANT_LN = 7    # 0/1: quantize layer-norm affine (gamma) parameters
SCALE_BUMP = 8  # 0/1: +1 on the shared exponent (Fig. 7 intervention)
BLOCK_SIZE_IDX = 9  # block size (16/32/64; 0 decodes as 32)
TWO_LEVEL = 10      # 0/1: NVFP4-style two-level (fp8 block × fp32 tensor) scaling
FMT_LEN = 11
FMT_LEN_V0 = 9      # original (pre-geometry) layout, still accepted by rust

# ---------------------------------------------------------------------------
# Runtime `hyper` vector layout: f32[HYPER_LEN].
# ---------------------------------------------------------------------------
LR = 0          # learning rate for this step
OPT_MODE = 1    # 0 = Adam, 1 = SGD(+momentum)
MOMENTUM = 2    # SGD momentum coefficient (0 = vanilla SGD)
LABEL_NOISE = 3 # std-dev of Gaussian label noise (proxy model)
HYPER_LEN = 4

ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8


def make_fmt(
    w_fwd: int = FP32,
    a_fwd: int = FP32,
    g_bwd: int | None = None,
    w_bwd: int | None = None,
    a_bwd: int | None = None,
    quant_fwd: bool = True,
    quant_bwd: bool = True,
    quant_ln: bool = True,
    scale_bump: bool = False,
    block_size: int = BLOCK_SIZE,
    two_level: bool = False,
):
    """Build the fmt vector (as a plain python list of floats).

    Backward formats default to the forward choices, matching the paper's
    default of using the same element type in both passes.  ``block_size``
    and ``two_level`` select the generalized block geometry (rust
    ``BlockGeom``); the defaults reproduce the classic OCP MX layout.
    """
    if block_size not in BLOCK_SIZES:
        raise ValueError(f"block_size {block_size} not in {BLOCK_SIZES}")
    g_bwd = a_fwd if g_bwd is None else g_bwd
    w_bwd = w_fwd if w_bwd is None else w_bwd
    a_bwd = a_fwd if a_bwd is None else a_bwd
    v = [0.0] * FMT_LEN
    v[W_FMT_FWD] = float(w_fwd)
    v[A_FMT_FWD] = float(a_fwd)
    v[G_FMT_BWD] = float(g_bwd)
    v[W_FMT_BWD] = float(w_bwd)
    v[A_FMT_BWD] = float(a_bwd)
    v[QUANT_FWD] = 1.0 if quant_fwd else 0.0
    v[QUANT_BWD] = 1.0 if quant_bwd else 0.0
    v[QUANT_LN] = 1.0 if quant_ln else 0.0
    v[SCALE_BUMP] = 1.0 if scale_bump else 0.0
    v[BLOCK_SIZE_IDX] = float(block_size)
    v[TWO_LEVEL] = 1.0 if two_level else 0.0
    return v
