"""Pure-jnp MX quantizer — the correctness oracle for the Pallas kernel and
the implementation used inside the compiled model step functions.

All format parameters are *runtime* scalars so a single lowered HLO module
serves every precision configuration (see DESIGN.md §1).  The math is
written so that every operation is exact in f32 except the final
round-half-to-even onto the element grid:

* ``floor(log2 |x|)`` is extracted from the f32 exponent bits (exact),
* powers of two are built with ``ldexp`` (exact),
* divisions/multiplications by powers of two are exact in f32.

This makes the jnp oracle, the Pallas kernel, and the rust mirror
bit-identical, which the test suites assert.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import formats as F


def _floor_log2(x):
    """floor(log2(x)) for positive normal f32 x, via exponent bits (exact)."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def _pow2(e):
    """2.0**e for integer-valued e (exact, handles subnormal results)."""
    return jnp.ldexp(jnp.float32(1.0), e.astype(jnp.int32))


def _select_constants(fmt_id):
    """Map a runtime format id scalar to (emax, max_norm, emin, mbits)."""
    fid = fmt_id.astype(jnp.float32)

    def pick(table, default):
        out = jnp.float32(default)
        for key, val in table.items():
            out = jnp.where(fid == key, jnp.float32(val), out)
        return out

    emax = pick({k: v[2] for k, v in F.MX_CONSTANTS.items()}, 0.0)
    maxn = pick({k: v[3] for k, v in F.MX_CONSTANTS.items()}, 1.0)
    emin = pick({k: v[4] for k, v in F.MX_CONSTANTS.items()}, 0.0)
    mbits = pick({k: v[1] for k, v in F.MX_CONSTANTS.items()}, 0.0)
    return emax, maxn, emin, mbits


def quantize_elem(r, fmt_id):
    """Quantize values (already divided by the block scale) onto the element
    grid of ``fmt_id``: round-half-even, subnormal-aware, clamped to
    ±max_norm (the paper's §6.1 clamping mechanism)."""
    emax, maxn, emin, mbits = _select_constants(fmt_id)
    a = jnp.abs(r)
    nz = a > 0
    safe = jnp.where(nz, a, jnp.float32(1.0))
    e = jnp.clip(_floor_log2(safe).astype(jnp.float32), emin, emax)
    # Quantization step for exponent band e: 2^(e - mbits).
    step = _pow2(e - mbits)
    q = jnp.round(a / step) * step  # exact scaling; RNE round
    q = jnp.minimum(q, maxn)        # overflow region → clamp to max normal
    q = jnp.where(nz, q, jnp.float32(0.0))
    return jnp.sign(r) * q


def mx_qdq_lastaxis(x, fmt_id, scale_bump):
    """MX block quantize→dequantize along the last axis (blocks of 32).

    Returns ``(y, last_bin)`` where ``last_bin`` is a boolean mask of
    elements that landed in the top quantization bin (|scaled| clamped or
    rounded to max_norm) — the paper's Fig. 5 diagnostic.
    """
    x = x.astype(jnp.float32)
    shape = x.shape
    assert shape[-1] % F.BLOCK_SIZE == 0, f"last axis {shape[-1]} % 32 != 0"
    xb = x.reshape(shape[:-1] + (shape[-1] // F.BLOCK_SIZE, F.BLOCK_SIZE))

    emax, maxn, emin, mbits = _select_constants(fmt_id)
    m = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    mz = m > 0
    msafe = jnp.where(mz, m, jnp.float32(1.0))
    shared_exp = _floor_log2(msafe).astype(jnp.float32) - emax + scale_bump
    scale = _pow2(shared_exp)
    r = xb / scale
    q = quantize_elem(r, fmt_id)
    last_bin = jnp.abs(q) >= maxn
    y = q * scale
    y = jnp.where(mz, y, jnp.float32(0.0))
    last_bin = jnp.logical_and(last_bin, mz)
    return y.reshape(shape), last_bin.reshape(shape)


def bf16_qdq(x):
    """Round-to-nearest-even bfloat16 cast, back to f32."""
    return x.astype(jnp.bfloat16).astype(jnp.float32)


def qdq(x, fmt_id, scale_bump, axis=-1):
    """Runtime-dispatch quantize→dequantize.

    fmt_id 0 → passthrough, 1 → bf16 cast, ≥2 → MX block quantization with
    blocks of 32 along ``axis``.  Returns ``(y, last_bin_mask)``.

    Dispatch uses ``lax.switch`` so only the *active* branch executes at
    runtime — fp32/bf16 configurations pay nothing for the MX math. (An
    earlier ``where``-blend of all three paths doubled step wallclock;
    see EXPERIMENTS.md §Perf.)
    """
    x = x.astype(jnp.float32)
    if axis != -1 and axis != x.ndim - 1:
        xm = jnp.moveaxis(x, axis, -1)
        y, lb = qdq(xm, fmt_id, scale_bump, axis=-1)
        return jnp.moveaxis(y, -1, axis), jnp.moveaxis(lb, -1, axis)

    fid = fmt_id.astype(jnp.float32)

    def branch_fp32(v):
        return v, jnp.zeros(v.shape, jnp.bool_)

    def branch_bf16(v):
        return bf16_qdq(v), jnp.zeros(v.shape, jnp.bool_)

    def branch_mx(v):
        return mx_qdq_lastaxis(v, fid, scale_bump)

    idx = jnp.clip(fid, 0.0, 2.0).astype(jnp.int32)
    return jax.lax.switch(idx, [branch_fp32, branch_bf16, branch_mx], x)


def qdq_ste(x, fmt_id, scale_bump, axis=-1):
    """Straight-through-estimator wrapper: forward = qdq, backward = identity.

    Matches the MX emulation library's autograd semantics for tensors that
    are quantized in place (e.g. layer-norm affine weights)."""
    y, lb = qdq(x, fmt_id, scale_bump, axis=axis)
    return x + jax.lax.stop_gradient(y - x), lb
