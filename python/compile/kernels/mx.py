"""L1 — Pallas MX quantize→dequantize kernel.

The paper's compute hot-spot: every GEMM operand is pushed through a
block-32 shared-scale quantizer.  This kernel implements that transform
with an explicit HBM→VMEM tiling schedule expressed through BlockSpec.

TPU mapping (DESIGN.md §Hardware-Adaptation): tiles are (TILE_R, TILE_C)
with TILE_C a multiple of 128 lanes, so each 128-lane vector register holds
four 32-element MX blocks; the shared-scale reduction is a width-32
segmented max, and the quantization itself is pure VPU element-wise math.
There is no MXU involvement — the kernel is memory-bound, and the BlockSpec
double-buffers HBM↔VMEM transfers tile by tile.

The kernel is lowered with ``interpret=True`` (mandatory for CPU-PJRT
execution; real TPU lowering emits a Mosaic custom-call the CPU plugin
cannot run) and checked against the pure-jnp oracle in ``ref.py`` by
pytest/hypothesis suites — they agree bit-for-bit.

Format parameters arrive as a scalar-prefetch-style small operand
(``fmt_ref``), so the same lowered module serves every element format.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import formats as F

# Tile shape: rows × lanes. 256 lanes = 2 vector registers = 8 MX blocks.
TILE_R = 8
TILE_C = 256


def _floor_log2(x):
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def _pow2(e):
    return jnp.ldexp(jnp.float32(1.0), e.astype(jnp.int32))


def _mx_qdq_tile(x, emax, maxn, emin, mbits, bump):
    """Quantize one (r, c) tile; c is a multiple of BLOCK_SIZE."""
    r, c = x.shape
    xb = x.reshape(r, c // F.BLOCK_SIZE, F.BLOCK_SIZE)
    m = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    mz = m > 0
    msafe = jnp.where(mz, m, jnp.float32(1.0))
    shared_exp = _floor_log2(msafe).astype(jnp.float32) - emax + bump
    scale = _pow2(shared_exp)
    rq = xb / scale

    a = jnp.abs(rq)
    nz = a > 0
    safe = jnp.where(nz, a, jnp.float32(1.0))
    e = jnp.clip(_floor_log2(safe).astype(jnp.float32), emin, emax)
    step = _pow2(e - mbits)
    q = jnp.round(a / step) * step
    q = jnp.minimum(q, maxn)
    q = jnp.where(nz, q, jnp.float32(0.0))
    y = jnp.sign(rq) * q * scale
    y = jnp.where(mz, y, jnp.float32(0.0))
    last = jnp.logical_and(jnp.abs(q) >= maxn, mz)
    return y.reshape(r, c), last.reshape(r, c).astype(jnp.float32)


def _kernel(fmt_ref, x_ref, y_ref, lb_ref):
    """Pallas kernel body: one VMEM tile per grid step.

    fmt_ref: f32[8] — [fmt_id, scale_bump, emax, max_norm, emin, mbits, _, _]
    (constants are pre-selected on the host side of the jaxpr so the kernel
    body stays pure element-wise math).
    """
    x = x_ref[...]
    fid = fmt_ref[0]
    bump = fmt_ref[1]
    emax, maxn, emin, mbits = fmt_ref[2], fmt_ref[3], fmt_ref[4], fmt_ref[5]
    y_mx, lb = _mx_qdq_tile(x, emax, maxn, emin, mbits, bump)
    y_bf = x.astype(jnp.bfloat16).astype(jnp.float32)
    y = jnp.where(fid == F.FP32, x, jnp.where(fid == F.BF16, y_bf, y_mx))
    lb = jnp.where(fid >= F.E4M3, lb, jnp.zeros_like(lb))
    y_ref[...] = y
    lb_ref[...] = lb


@functools.partial(jax.jit, static_argnames=("interpret",))
def mx_qdq_pallas(x, fmt_id, scale_bump, interpret=True):
    """Block-32 MX quantize→dequantize over the last axis of a 2-D array.

    Returns (y, last_bin_fraction_mask as f32).  Shape must tile by
    (TILE_R, TILE_C); the model layer shapes used in this repo all do.
    """
    x = x.astype(jnp.float32)
    rows, cols = x.shape
    tr = TILE_R if rows % TILE_R == 0 else rows
    tc = TILE_C if cols % TILE_C == 0 else cols
    assert cols % F.BLOCK_SIZE == 0, f"cols {cols} % 32 != 0"

    # Pre-select format constants (tiny scalar jnp graph, runs once per call)
    from . import ref

    emax, maxn, emin, mbits = ref._select_constants(jnp.asarray(fmt_id))
    fmt_op = jnp.stack(
        [
            jnp.asarray(fmt_id, jnp.float32),
            jnp.asarray(scale_bump, jnp.float32),
            emax,
            maxn,
            emin,
            mbits,
            jnp.float32(0),
            jnp.float32(0),
        ]
    )

    grid = (rows // tr, cols // tc)
    y, lb = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((8,), lambda i, j: (0,)),
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
            pl.BlockSpec((tr, tc), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), jnp.float32),
            jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        ],
        interpret=interpret,
    )(fmt_op, x)
    return y, lb
