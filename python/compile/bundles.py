"""Artifact bundle registry — the single list of (model-shape) bundles that
``aot.py`` lowers and the rust coordinator loads.

Set names:
  * ``quick``   — minimal set for CI / pytest / cargo test (seconds to build)
  * ``default`` — everything the paper-figure experiments need
  * ``full``    — default + larger LM rungs for longer scaling-law ladders

The *precision format* is NOT part of a bundle: it is a runtime input to
every step executable (DESIGN.md §1), so one bundle per model shape covers
the paper's entire format sweep.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from .lm import LMConfig
from .proxy import ProxyConfig


@dataclasses.dataclass(frozen=True)
class Bundle:
    cfg: object            # ProxyConfig | LMConfig | str ("quantizer")
    paired: bool = False   # also emit paired.hlo.txt (Fig. 4 diagnostics)
    use_pallas: bool = False  # route quantization through the Pallas kernel

    @property
    def name(self) -> str:
        if isinstance(self.cfg, str):
            return self.cfg
        suffix = "_pallas" if self.use_pallas else ""
        return self.cfg.name + suffix


def _proxy_grid(depths: Iterable[int], widths: Iterable[int], batch: int):
    return [
        Bundle(ProxyConfig(depth=L, d_model=D, batch=batch))
        for L in depths
        for D in widths
    ]


def bundle_set(name: str) -> list[Bundle]:
    if name == "quick":
        return [
            Bundle("quantizer"),
            Bundle(ProxyConfig(depth=2, d_model=128, batch=64), paired=True),
            Bundle(ProxyConfig(depth=2, d_model=128, batch=64), use_pallas=True),
            Bundle(LMConfig(n=1, vocab=256, ctx=64, batch=8), paired=True),
        ]
    if name in ("default", "full"):
        bundles = [Bundle("quantizer")]
        # Fig. 2 / 9 depth–width grid (gelu + LN). Paper: D ∈ [384, 768],
        # L ∈ [3, 6] is the interesting band; batch scaled 2048→256 for CPU.
        grid = _proxy_grid((2, 3, 4), (128, 256, 384), batch=128)
        # Fig. 4/6/7 anchor config (paper: L=4, D=512; here L=4, D=384 —
        # the CPU-scale substitution documented in DESIGN.md) gets paired
        # gradients.
        bundles += [
            b
            if not (b.cfg.depth == 4 and b.cfg.d_model == 256)
            else Bundle(b.cfg, paired=True)
            for b in grid
        ]
        # Fig. 3 activation × layernorm ablation at the anchor size.
        for act in ("relu", "gelu", "swiglu"):
            for ln in (True, False):
                if act == "gelu" and ln:
                    continue  # already in the grid
                bundles.append(
                    Bundle(ProxyConfig(depth=4, d_model=256, batch=128,
                                       activation=act, layernorm=ln))
                )
        # Pallas-integrated proxy (proves L1∘L2∘L3 composition end-to-end).
        bundles.append(
            Bundle(ProxyConfig(depth=2, d_model=256, batch=128), use_pallas=True)
        )
        # LM ladder (Table 3 geometry: depth = heads = n, d_model = 64n).
        rungs = (1, 2, 3) if name == "default" else (1, 2, 3, 4)
        bundles += [
            Bundle(LMConfig(n=n, vocab=512, ctx=64, batch=16), paired=(n == 2))
            for n in rungs
        ]
        return bundles
    raise ValueError(f"unknown bundle set {name!r}")
