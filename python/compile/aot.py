"""AOT compiler: lower every bundle's functions to HLO *text* + manifest.

HLO text (not ``HloModuleProto.serialize``) is the interchange format: jax
≥0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out ../artifacts --set default [--force]

Python runs ONCE — the rust binary is self-contained after this.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import bundles as B
from . import formats as F
from . import lm as lm_mod
from . import model as M
from . import proxy as proxy_mod

QUANTIZER_SHAPE = (128, 512)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name, arr_spec):
    return {
        "name": name,
        "shape": list(arr_spec.shape),
        "dtype": str(arr_spec.dtype),
    }


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _lower(fn, args):
    # keep_unused: jit would otherwise DCE unused scalar params (e.g. the
    # LM init ignores init_mode/gain) and change the executable arity.
    return jax.jit(fn, keep_unused=True).lower(*args)


def _write(outdir, fname, text):
    path = os.path.join(outdir, fname)
    with open(path, "w") as f:
        f.write(text)
    return hashlib.sha256(text.encode()).hexdigest()[:16]


SCALARS = {
    "seed": _sds((), jnp.int32),
    "step": _sds((), jnp.int32),
    "init_mode": _sds((), jnp.float32),
    "gain": _sds((), jnp.float32),
}
FMT_SDS = _sds((F.FMT_LEN,))
HYPER_SDS = _sds((F.HYPER_LEN,))

METRIC_NAMES = [
    "loss",
    "grad_norm",
    "ln_frac_first",
    "ln_frac_mean",
    "act_frac_mean",
    "update_norm",
    "param_norm",
    "eps_ratio",
    "cosine",
]


def compile_quantizer(outdir):
    """Standalone Pallas mx_qdq module (golden tests + L1 benches)."""
    from .kernels import mx as mxk

    rows, cols = QUANTIZER_SHAPE

    def fn(x, fmt_id, bump):
        y, lb = mxk.mx_qdq_pallas(x, fmt_id, bump, interpret=True)
        return y, jnp.mean(lb)

    args = (_sds((rows, cols)), _sds((), jnp.float32), _sds((), jnp.float32))
    h = _write(outdir, "step.hlo.txt", to_hlo_text(_lower(fn, args)))
    manifest = {
        "kind": "quantizer",
        "name": "quantizer",
        "block_size": F.BLOCK_SIZE,
        "formats": {v: k for k, v in F.FORMAT_NAMES.items()},
        "functions": {
            "step": {
                "file": "step.hlo.txt",
                "sha": h,
                "inputs": [
                    {"name": "x", "shape": [rows, cols], "dtype": "float32"},
                    {"name": "fmt_id", "shape": [], "dtype": "float32"},
                    {"name": "scale_bump", "shape": [], "dtype": "float32"},
                ],
                "outputs": [
                    {"name": "y", "shape": [rows, cols], "dtype": "float32"},
                    {"name": "last_bin_frac", "shape": [], "dtype": "float32"},
                ],
            }
        },
    }
    _write(outdir, "manifest.json", json.dumps(manifest, indent=1))


def _state_inputs(spec):
    return [{"name": n, "shape": list(sh), "dtype": "float32"} for n, sh in spec]


def _common_manifest(kind, bundle, cfg, spec):
    return {
        "kind": kind,
        "name": bundle.name,
        "config": {
            k: getattr(cfg, k)
            for k in cfg.__dataclass_fields__  # type: ignore[attr-defined]
        },
        "n_params": cfg.n_params(),
        "state": _state_inputs(spec),
        "fmt_len": F.FMT_LEN,
        "hyper_len": F.HYPER_LEN,
        "formats": {v: k for k, v in F.FORMAT_NAMES.items()},
        "metrics": METRIC_NAMES,
        "use_pallas": bundle.use_pallas,
        "functions": {},
    }


def compile_proxy(bundle, outdir):
    cfg = bundle.cfg
    spec = proxy_mod.state_spec(cfg)
    state_sds = tuple(_sds(sh) for _, sh in spec)

    man = _common_manifest("proxy", bundle, cfg, spec)

    init = proxy_mod.make_init(cfg)
    h = _write(
        outdir,
        "init.hlo.txt",
        to_hlo_text(_lower(init, (SCALARS["seed"], SCALARS["init_mode"], SCALARS["gain"]))),
    )
    man["functions"]["init"] = {
        "file": "init.hlo.txt",
        "sha": h,
        "inputs": [
            {"name": "seed", "shape": [], "dtype": "int32"},
            {"name": "init_mode", "shape": [], "dtype": "float32"},
            {"name": "gain", "shape": [], "dtype": "float32"},
        ],
        "outputs": _state_inputs(spec),
    }

    step_inputs = [
        *_state_inputs(spec),
        {"name": "fmt", "shape": [F.FMT_LEN], "dtype": "float32"},
        {"name": "hyper", "shape": [F.HYPER_LEN], "dtype": "float32"},
        {"name": "seed", "shape": [], "dtype": "int32"},
        {"name": "step", "shape": [], "dtype": "int32"},
    ]
    step_outputs = [
        *_state_inputs(spec),
        {"name": "metrics", "shape": [M.MET_LEN], "dtype": "float32"},
    ]
    variants = [("step", False)] + ([("paired", True)] if bundle.paired else [])
    for fname, paired in variants:
        fn = proxy_mod.make_step(cfg, paired=paired)
        lowered = _lower(
            lambda st, fmt, hy, se, stp: fn(st, fmt, hy, se, stp),
            (state_sds, FMT_SDS, HYPER_SDS, SCALARS["seed"], SCALARS["step"]),
        )
        h = _write(outdir, f"{fname}.hlo.txt", to_hlo_text(lowered))
        man["functions"][fname] = {
            "file": f"{fname}.hlo.txt",
            "sha": h,
            "inputs": step_inputs,
            "outputs": step_outputs,
        }
    _write(outdir, "manifest.json", json.dumps(man, indent=1))


def compile_lm(bundle, outdir):
    cfg = bundle.cfg
    spec = lm_mod.state_spec(cfg)
    state_sds = tuple(_sds(sh) for _, sh in spec)
    tokens_sds = _sds((cfg.batch, cfg.ctx + 1), jnp.int32)

    man = _common_manifest("lm", bundle, cfg, spec)
    man["flops_per_step"] = cfg.flops_per_step()

    init = lm_mod.make_init(cfg)
    h = _write(
        outdir,
        "init.hlo.txt",
        to_hlo_text(_lower(init, (SCALARS["seed"], SCALARS["init_mode"], SCALARS["gain"]))),
    )
    man["functions"]["init"] = {
        "file": "init.hlo.txt",
        "sha": h,
        "inputs": [
            {"name": "seed", "shape": [], "dtype": "int32"},
            {"name": "init_mode", "shape": [], "dtype": "float32"},
            {"name": "gain", "shape": [], "dtype": "float32"},
        ],
        "outputs": _state_inputs(spec),
    }

    tok_input = {
        "name": "tokens",
        "shape": [cfg.batch, cfg.ctx + 1],
        "dtype": "int32",
    }
    step_inputs = [
        *_state_inputs(spec),
        tok_input,
        {"name": "fmt", "shape": [F.FMT_LEN], "dtype": "float32"},
        {"name": "hyper", "shape": [F.HYPER_LEN], "dtype": "float32"},
        {"name": "seed", "shape": [], "dtype": "int32"},
        {"name": "step", "shape": [], "dtype": "int32"},
    ]
    step_outputs = [
        *_state_inputs(spec),
        {"name": "metrics", "shape": [M.MET_LEN], "dtype": "float32"},
    ]
    variants = [("step", False)] + ([("paired", True)] if bundle.paired else [])
    for fname, paired in variants:
        fn = lm_mod.make_step(cfg, paired=paired)
        lowered = _lower(
            fn, (state_sds, tokens_sds, FMT_SDS, HYPER_SDS, SCALARS["seed"], SCALARS["step"])
        )
        h = _write(outdir, f"{fname}.hlo.txt", to_hlo_text(lowered))
        man["functions"][fname] = {
            "file": f"{fname}.hlo.txt",
            "sha": h,
            "inputs": step_inputs,
            "outputs": step_outputs,
        }

    # eval: params only (first third of the state), tokens, fmt → loss
    k = len(spec) // 3
    ev = lm_mod.make_eval(cfg)
    lowered = _lower(ev, (tuple(_sds(sh) for _, sh in spec[:k]), tokens_sds, FMT_SDS))
    h = _write(outdir, "eval.hlo.txt", to_hlo_text(lowered))
    man["functions"]["eval"] = {
        "file": "eval.hlo.txt",
        "sha": h,
        "inputs": [
            *_state_inputs(spec[:k]),
            tok_input,
            {"name": "fmt", "shape": [F.FMT_LEN], "dtype": "float32"},
        ],
        "outputs": [{"name": "loss", "shape": [], "dtype": "float32"}],
    }
    _write(outdir, "manifest.json", json.dumps(man, indent=1))


def build(outroot: str, set_name: str, force: bool, only: str | None = None):
    os.makedirs(outroot, exist_ok=True)
    built, skipped = [], []
    bundle_list = B.bundle_set(set_name)
    for bundle in bundle_list:
        if only and only not in bundle.name:
            continue
        outdir = os.path.join(outroot, bundle.name)
        stamp = os.path.join(outdir, "manifest.json")
        if not force and os.path.exists(stamp):
            skipped.append(bundle.name)
            continue
        os.makedirs(outdir, exist_ok=True)
        M.set_use_pallas(bundle.use_pallas)
        try:
            if isinstance(bundle.cfg, str):
                compile_quantizer(outdir)
            elif isinstance(bundle.cfg, proxy_mod.ProxyConfig):
                compile_proxy(bundle, outdir)
            else:
                compile_lm(bundle, outdir)
        finally:
            M.set_use_pallas(False)
        built.append(bundle.name)
        print(f"[aot] built {bundle.name}", flush=True)
    # Index = union of every bundle present on disk (multiple sets coexist).
    present = sorted(
        d
        for d in os.listdir(outroot)
        if os.path.exists(os.path.join(outroot, d, "manifest.json"))
    )
    index = {"set": set_name, "bundles": present}
    with open(os.path.join(outroot, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"[aot] done: {len(built)} built, {len(skipped)} up-to-date")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--set", default=os.environ.get("MXSTAB_SET", "default"))
    p.add_argument("--force", action="store_true")
    p.add_argument("--only", default=None, help="substring filter on bundle names")
    args = p.parse_args()
    build(args.out, args.set, args.force, args.only)


if __name__ == "__main__":
    main()
