"""L2 shared model components.

* ``mx_matmul`` — GEMM whose operands pass through the MX quantizer in both
  the forward and the backward pass, with independently selectable element
  formats (the paper's quantization sites: Linear / MatMul / BMM inputs).
* ``layernorm`` — layer normalization whose affine (gamma) parameter is
  block-quantized (the paper's §6.1 instability mechanism).
* ``adam_sgd_update`` — fused optimizer with runtime-selectable Adam / SGD(m).

Every quantization site records the fraction of elements that land in the
last quantization bin (Fig. 5 diagnostics); the step functions aggregate
these into the metrics vector the rust coordinator logs.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from . import formats as F
from .kernels import ref

# When enabled, forward activation quantization in 2-D matmuls routes
# through the Pallas kernel (L1) so it lowers into the same HLO module.
# The jnp path is bit-identical (asserted by pytest) and lowers to a far
# smaller, fusible HLO graph, so it is the default for big sweep bundles;
# aot.py flips this on for the pallas-integrated bundles.
_USE_PALLAS = os.environ.get("MXSTAB_PALLAS", "0") == "1"


def set_use_pallas(on: bool):
    """Route eligible quantization sites through the Pallas kernel for
    functions traced after this call (used by aot.py per-bundle)."""
    global _USE_PALLAS
    _USE_PALLAS = bool(on)


def _q(x, fmt_id, bump, axis):
    """Quantize-dequantize returning (values, last-bin fraction scalar)."""
    if _USE_PALLAS and x.ndim == 2 and axis in (-1, 1) and x.shape[1] % 256 == 0:
        from .kernels import mx as mxk

        y, lb = mxk.mx_qdq_pallas(x, fmt_id, bump, interpret=True)
        return y, jnp.mean(lb)
    y, lb = ref.qdq(x, fmt_id, bump, axis=axis)
    return y, jnp.mean(lb.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Quantized matmul with custom VJP.
#
# Forward:   out = Q_a(x) @ Q_w(w)           (blocks along the K axis)
# Backward:  dx  = Q_g(g) @ Q_w(w).T         (blocks along the N axis)
#            dw  = Q_a(x).T @ Q_g(g)         (blocks along the B axis)
#
# fmt is the 9-element runtime configuration vector (formats.py layout);
# flags QUANT_FWD / QUANT_BWD gate each pass (1.0 → quantize).
# ---------------------------------------------------------------------------


def _maybe(x, enable, fmt_id, bump, axis):
    """Quantize when ``enable`` is set. Folding the enable flag into the
    format id (0 = fp32 passthrough) lets the qdq ``lax.switch`` skip the
    MX math entirely when quantization is off."""
    eff_id = jnp.where(enable > 0.5, fmt_id, jnp.float32(F.FP32))
    return _q(x, eff_id, bump, axis)


@jax.custom_vjp
def mx_matmul(x, w, fmt):
    y, _ = _mx_matmul_fwd_impl(x, w, fmt)
    return y


def _mx_matmul_fwd_impl(x, w, fmt):
    bump = fmt[F.SCALE_BUMP]
    qx, fx = _maybe(x, fmt[F.QUANT_FWD], fmt[F.A_FMT_FWD], bump, axis=-1)
    qw, fw = _maybe(w, fmt[F.QUANT_FWD], fmt[F.W_FMT_FWD], bump, axis=0)
    return qx @ qw, (fx + fw) * 0.5


def _mx_matmul_fwd(x, w, fmt):
    y, _ = _mx_matmul_fwd_impl(x, w, fmt)
    return y, (x, w, fmt)


def _mx_matmul_bwd(res, g):
    x, w, fmt = res
    bump = fmt[F.SCALE_BUMP]
    en = fmt[F.QUANT_BWD]
    # dx = g @ w.T : reduction over N → g blocked on last axis, w on axis 1.
    qg_n, _ = _maybe(g, en, fmt[F.G_FMT_BWD], bump, axis=-1)
    qw_n, _ = _maybe(w, en, fmt[F.W_FMT_BWD], bump, axis=1)
    dx = qg_n @ qw_n.T
    # dw = x.T @ g : reduction over batch → both blocked on axis 0.
    qx_b, _ = _maybe(x, en, fmt[F.A_FMT_BWD], bump, axis=0)
    qg_b, _ = _maybe(g, en, fmt[F.G_FMT_BWD], bump, axis=0)
    dw = qx_b.T @ qg_b
    return dx, dw, jnp.zeros_like(fmt)


mx_matmul.defvjp(_mx_matmul_fwd, _mx_matmul_bwd)


def mx_matmul_stats(x, w, fmt):
    """Like mx_matmul but also returns the forward activation last-bin
    fraction (Fig. 5 right diagnostic). Differentiable via the custom VJP;
    the diagnostic is quantizer-only (no extra GEMM)."""
    y = mx_matmul(x, w, fmt)
    xs = jax.lax.stop_gradient(x)
    _, frac = _maybe(xs, fmt[F.QUANT_FWD], fmt[F.A_FMT_FWD], fmt[F.SCALE_BUMP], axis=-1)
    return y, frac


# ---------------------------------------------------------------------------
# Batched (rank-3) quantized matmul for attention BMMs.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def mx_bmm(a, b, fmt):
    qa, _ = _maybe(a, fmt[F.QUANT_FWD], fmt[F.A_FMT_FWD], fmt[F.SCALE_BUMP], axis=-1)
    qb, _ = _maybe(b, fmt[F.QUANT_FWD], fmt[F.A_FMT_FWD], fmt[F.SCALE_BUMP], axis=-2)
    return qa @ qb


def _mx_bmm_fwd(a, b, fmt):
    return mx_bmm(a, b, fmt), (a, b, fmt)


def _mx_bmm_bwd(res, g):
    a, b, fmt = res
    bump = fmt[F.SCALE_BUMP]
    en = fmt[F.QUANT_BWD]
    gid = fmt[F.G_FMT_BWD]
    aid = fmt[F.A_FMT_BWD]
    qg_n, _ = _maybe(g, en, gid, bump, axis=-1)
    qb_n, _ = _maybe(b, en, aid, bump, axis=-1)
    da = qg_n @ jnp.swapaxes(qb_n, -1, -2)
    qa_k, _ = _maybe(a, en, aid, bump, axis=-2)
    qg_k, _ = _maybe(g, en, gid, bump, axis=-2)
    db = jnp.swapaxes(qa_k, -1, -2) @ qg_k
    return da, db, jnp.zeros_like(fmt)


mx_bmm.defvjp(_mx_bmm_fwd, _mx_bmm_bwd)


# ---------------------------------------------------------------------------
# Layer normalization with quantized affine weight.
# ---------------------------------------------------------------------------


def layernorm(x, gamma, fmt, eps=1e-5):
    """LN(x) = gamma_q ⊙ (x - mean)/sqrt(var + eps).

    gamma is quantized with the *weight* forward format when QUANT_LN is on
    (straight-through in the backward pass, matching the emulation library).
    Returns (out, last_bin_fraction_of_gamma) — the Fig. 5 middle diagnostic.
    The vector arithmetic itself runs in bf16-or-better, as in the paper.
    """
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    xhat = (x - mu) * jax.lax.rsqrt(var + eps)
    on = jnp.logical_and(fmt[F.QUANT_LN] > 0.5, fmt[F.QUANT_FWD] > 0.5)
    eff_id = jnp.where(on, fmt[F.W_FMT_FWD], jnp.float32(F.FP32))
    g_eff, lb = ref.qdq_ste(gamma, eff_id, fmt[F.SCALE_BUMP], axis=-1)
    frac = jnp.mean(lb.astype(jnp.float32))
    return xhat * g_eff, jax.lax.stop_gradient(frac)


# ---------------------------------------------------------------------------
# Optimizer: fused Adam / SGD(momentum), runtime-selectable.
# ---------------------------------------------------------------------------


def adam_sgd_update(p, g, m, v, step, hyper):
    """One optimizer update for a single tensor.

    hyper[OPT_MODE] = 0 → Adam(b1=0.9, b2=0.95, eps=1e-8, bias-corrected)
                    = 1 → SGD with momentum hyper[MOMENTUM] (0 → vanilla).
    Master weights and optimizer state stay in f32 (as in the paper).
    """
    lr = hyper[F.LR]
    mode = hyper[F.OPT_MODE]
    mu = hyper[F.MOMENTUM]
    t = step.astype(jnp.float32) + 1.0

    m_adam = F.ADAM_B1 * m + (1.0 - F.ADAM_B1) * g
    v_adam = F.ADAM_B2 * v + (1.0 - F.ADAM_B2) * g * g
    mhat = m_adam / (1.0 - F.ADAM_B1**t)
    vhat = v_adam / (1.0 - F.ADAM_B2**t)
    upd_adam = mhat / (jnp.sqrt(vhat) + F.ADAM_EPS)

    m_sgd = mu * m + g
    upd_sgd = m_sgd

    is_sgd = mode > 0.5
    m_new = jnp.where(is_sgd, m_sgd, m_adam)
    v_new = jnp.where(is_sgd, v, v_adam)
    upd = jnp.where(is_sgd, upd_sgd, upd_adam)
    return p - lr * upd, m_new, v_new


def tree_update(params, grads, ms, vs, step, hyper):
    """Apply adam_sgd_update across a pytree; returns (params', ms', vs',
    update_norm^2 accumulated)."""
    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(ms)
    leaves_v = treedef.flatten_up_to(vs)
    new_p, new_m, new_v = [], [], []
    upd_sq = jnp.float32(0.0)
    for p, g, m, v in zip(leaves_p, leaves_g, leaves_m, leaves_v):
        p2, m2, v2 = adam_sgd_update(p, g, m, v, step, hyper)
        upd_sq = upd_sq + jnp.sum((p2 - p) ** 2)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)
    return (
        treedef.unflatten(new_p),
        treedef.unflatten(new_m),
        treedef.unflatten(new_v),
        upd_sq,
    )


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32)**2) for l in leaves))


def tree_dot(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return sum(jnp.sum(x * y) for x, y in zip(la, lb))


# Metrics vector layout — must match rust/src/coordinator/metrics.rs.
MET_LOSS = 0
MET_GRAD_NORM = 1
MET_LN_FRAC_FIRST = 2   # last-bin fraction of first-layer LN gamma
MET_LN_FRAC_MEAN = 3    # mean over all LN gammas
MET_ACT_FRAC_MEAN = 4   # mean over forward GEMM operand sites
MET_UPDATE_NORM = 5
MET_PARAM_NORM = 6
MET_EPS_RATIO = 7       # paired mode: ||g_mx - g_fp32|| / ||g_fp32||
MET_COSINE = 8          # paired mode: cos(g_mx, g_fp32)
MET_LEN = 9
