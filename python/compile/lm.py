"""L2 — OLMo-style decoder-only transformer LM (paper §3 / Table 3).

Architecture (matching the paper's Table 3):
  * n layers, n heads, head dim 64 → d_model = 64·n
  * pre-LN blocks, GeLU MLP with 4× hidden multiplier, no biases
  * RoPE positional encoding
  * QK normalization (layernorm over head dim with affine gamma — one of
    the paper's clamping-prone parameter groups)
  * untied output head, final layernorm
  * cross-entropy next-token loss

All Linear / BMM inputs pass through the MX quantizer exactly as in the MX
emulation library: weight + activation operands in the forward pass, and
gradient/weight/activation operands in the backward pass, each with its own
runtime-selectable element format (python/compile/formats.py).

Token batches are produced by the rust coordinator's synthetic Zipf–Markov
corpus and passed in as an i32 tensor [batch, ctx+1].
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import formats as F
from . import model as M


@dataclasses.dataclass(frozen=True)
class LMConfig:
    n: int = 2              # depth = heads = n; d_model = 64 n (Table 3)
    vocab: int = 512
    ctx: int = 64
    batch: int = 16

    @property
    def d_model(self) -> int:
        return 64 * self.n

    @property
    def heads(self) -> int:
        return self.n

    @property
    def head_dim(self) -> int:
        return 64

    @property
    def hidden(self) -> int:
        return 4 * self.d_model

    @property
    def name(self) -> str:
        return f"lm_n{self.n}_v{self.vocab}_c{self.ctx}_b{self.batch}"

    def n_params(self) -> int:
        D, H, V = self.d_model, self.hidden, self.vocab
        per_layer = 4 * D * D + 2 * D * H + 2 * D + 2 * self.head_dim
        return self.n * per_layer + 2 * V * D + D

    def flops_per_step(self) -> int:
        """~6 N D_tokens forward+backward GEMM FLOPs (Chinchilla accounting)."""
        return 6 * self.n_params() * self.batch * self.ctx


# --------------------------------------------------------------------------
# Parameters (stacked over layers for lax.scan).
# --------------------------------------------------------------------------

PARAM_SHAPES = lambda c: {
    "embed": (c.vocab, c.d_model),
    "wq": (c.n, c.d_model, c.d_model),
    "wk": (c.n, c.d_model, c.d_model),
    "wv": (c.n, c.d_model, c.d_model),
    "wo": (c.n, c.d_model, c.d_model),
    "wi": (c.n, c.d_model, c.hidden),
    "wf": (c.n, c.hidden, c.d_model),
    "ln1": (c.n, c.d_model),
    "ln2": (c.n, c.d_model),
    "lnq": (c.n, c.head_dim),
    "lnk": (c.n, c.head_dim),
    "lnf": (c.d_model,),
    "head": (c.d_model, c.vocab),
}


def init_params(cfg: LMConfig, key):
    shapes = PARAM_SHAPES(cfg)
    params = {}
    for i, (n, sh) in enumerate(sorted(shapes.items())):
        k = jax.random.fold_in(key, i)
        if n.startswith("ln"):
            params[n] = jnp.ones(sh, jnp.float32)
        elif n == "embed":
            params[n] = jax.random.normal(k, sh, jnp.float32) * 0.02
        else:
            fan_in = sh[-2]
            params[n] = jax.random.normal(k, sh, jnp.float32) / jnp.sqrt(
                jnp.float32(fan_in)
            )
    return params


def _rope(x):
    """Rotary embedding over the last axis of [B, H, T, Dh]."""
    b, h, t, d = x.shape
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def forward(cfg: LMConfig, params, tokens, fmt):
    """tokens: i32[B, T] → logits f32[B, T, V]; returns (logits, diag)."""
    B, T = tokens.shape
    D, H, Dh, nh = cfg.d_model, cfg.hidden, cfg.head_dim, cfg.heads
    x = params["embed"][tokens]  # [B, T, D]

    mask = jnp.tril(jnp.ones((T, T), jnp.float32))
    neg = jnp.float32(-1e9)

    layer_params = tuple(
        params[n] for n in ("wq", "wk", "wv", "wo", "wi", "wf", "ln1", "ln2", "lnq", "lnk")
    )

    def block(carry, layer):
        x = carry
        wq, wk, wv, wo, wi, wf, g1, g2, gq, gk = layer
        # --- attention ---
        z, lnf1 = M.layernorm(x, g1, fmt)
        z2 = z.reshape(B * T, D)
        q, fq = M.mx_matmul_stats(z2, wq, fmt)
        k, _ = M.mx_matmul_stats(z2, wk, fmt)
        v, _ = M.mx_matmul_stats(z2, wv, fmt)

        def heads(u):
            return u.reshape(B, T, nh, Dh).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        # QK normalization (Henry et al. 2020) — affine gammas quantize like
        # any other LN parameter (a clamping-prone group per the paper §6.1).
        q, lnfq = M.layernorm(q, gq, fmt)
        k, lnfk = M.layernorm(k, gk, fmt)
        q, k = _rope(q), _rope(k)

        qf = q.reshape(B * nh, T, Dh)
        kf = k.reshape(B * nh, T, Dh)
        vf = v.reshape(B * nh, T, Dh)
        att = M.mx_bmm(qf, jnp.swapaxes(kf, -1, -2), fmt) / jnp.sqrt(
            jnp.float32(Dh)
        )
        att = att + (1.0 - mask) * neg
        att = jax.nn.softmax(att, axis=-1)
        o = M.mx_bmm(att, vf, fmt)
        o = o.reshape(B, nh, T, Dh).transpose(0, 2, 1, 3).reshape(B * T, D)
        o, _ = M.mx_matmul_stats(o, wo, fmt)
        x = x + o.reshape(B, T, D)
        # --- mlp ---
        z, lnf2 = M.layernorm(x, g2, fmt)
        hline, fh = M.mx_matmul_stats(z.reshape(B * T, D), wi, fmt)
        hact = jax.nn.gelu(hline)
        out, _ = M.mx_matmul_stats(hact, wf, fmt)
        x = x + out.reshape(B, T, D)
        ln_frac_ffn = lnf2  # the paper's Fig. 5 tracks the FFN layernorm
        ln_frac_mean = (lnf1 + lnf2 + lnfq + lnfk) / 4.0
        return x, (ln_frac_ffn, ln_frac_mean, (fq + fh) * 0.5)

    x, (ffn_fracs, ln_means, act_fracs) = jax.lax.scan(block, x, layer_params)

    z, lnff = M.layernorm(x, params["lnf"], fmt)
    logits, _ = M.mx_matmul_stats(z.reshape(B * T, D), params["head"], fmt)
    diag = (
        ffn_fracs[0],
        (jnp.mean(ln_means) * cfg.n + lnff) / (cfg.n + 1),
        jnp.mean(act_fracs),
    )
    return logits.reshape(B, T, cfg.vocab), diag


def loss_fn(cfg: LMConfig, params, tokens, fmt):
    """Next-token cross-entropy over tokens[:, :-1] → tokens[:, 1:]."""
    logits, diag = forward(cfg, params, tokens[:, :-1], fmt)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return -jnp.mean(ll), diag


# --------------------------------------------------------------------------
# Exported functions.
# --------------------------------------------------------------------------


def state_spec(cfg: LMConfig):
    shapes = PARAM_SHAPES(cfg)
    names = sorted(shapes.keys())
    spec = []
    for prefix in ("p", "m", "v"):
        for n in names:
            spec.append((f"{prefix}_{n}", shapes[n]))
    return spec


def _unflatten(cfg: LMConfig, flat):
    names = sorted(PARAM_SHAPES(cfg).keys())
    k = len(names)
    params = dict(zip(names, flat[:k]))
    ms = dict(zip(names, flat[k : 2 * k]))
    vs = dict(zip(names, flat[2 * k : 3 * k]))
    return params, ms, vs


def _flatten(cfg: LMConfig, params, ms, vs):
    names = sorted(PARAM_SHAPES(cfg).keys())
    return [params[n] for n in names] + [ms[n] for n in names] + [vs[n] for n in names]


def make_init(cfg: LMConfig):
    def init(seed, init_mode, gain):
        del init_mode, gain  # LM uses the fixed OLMo-style init
        params = init_params(cfg, jax.random.PRNGKey(seed))
        ms = {n: jnp.zeros_like(p) for n, p in params.items()}
        vs = {n: jnp.zeros_like(p) for n, p in params.items()}
        return tuple(_flatten(cfg, params, ms, vs))

    return init


def make_step(cfg: LMConfig, paired: bool = False):
    def step(flat_state, tokens, fmt, hyper, seed, step_idx):
        del seed
        params, ms, vs = _unflatten(cfg, list(flat_state))
        grad_fn = jax.value_and_grad(
            lambda p, f: loss_fn(cfg, p, tokens, f), has_aux=True
        )
        (loss, diag), grads = grad_fn(params, fmt)

        extra = None
        if paired:
            (_, _), g_ref = grad_fn(params, jnp.zeros_like(fmt))
            diff_sq = sum(
                jnp.sum((a - b) ** 2)
                for a, b in zip(
                    jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(g_ref)
                )
            )
            ref_norm = M.global_norm(g_ref)
            extra = (
                jnp.sqrt(diff_sq) / (ref_norm + 1e-30),
                M.tree_dot(grads, g_ref) / (M.global_norm(grads) * ref_norm + 1e-30),
            )

        params2, ms2, vs2, upd_sq = M.tree_update(params, grads, ms, vs, step_idx, hyper)

        met = jnp.zeros((M.MET_LEN,), jnp.float32)
        met = met.at[M.MET_LOSS].set(loss)
        met = met.at[M.MET_GRAD_NORM].set(M.global_norm(grads))
        met = met.at[M.MET_LN_FRAC_FIRST].set(diag[0])
        met = met.at[M.MET_LN_FRAC_MEAN].set(diag[1])
        met = met.at[M.MET_ACT_FRAC_MEAN].set(diag[2])
        met = met.at[M.MET_UPDATE_NORM].set(jnp.sqrt(upd_sq))
        met = met.at[M.MET_PARAM_NORM].set(M.global_norm(params2))
        if extra is not None:
            met = met.at[M.MET_EPS_RATIO].set(extra[0])
            met = met.at[M.MET_COSINE].set(extra[1])
        return tuple(_flatten(cfg, params2, ms2, vs2)) + (met,)

    return step


def make_eval(cfg: LMConfig):
    """Validation-loss function: (flat params only, tokens, fmt) → loss."""

    def ev(flat_params, tokens, fmt):
        names = sorted(PARAM_SHAPES(cfg).keys())
        params = dict(zip(names, flat_params))
        loss, _ = loss_fn(cfg, params, tokens, fmt)
        return (loss,)

    return ev
