"""L2 — residual-MLP student–teacher proxy model (paper Eq. 1).

Student:  A_0 = x;  h_k = W1_k · LN_k(A_{k-1});  A_k = A_{k-1} + W2_k · φ(h_k)
Teacher:  identical architecture *without* layer normalization.
Targets:  y = teacher(x) + σ·ε,  σ = hyper[LABEL_NOISE],  ε ~ N(0, I).
Loss:     MSE.

Inputs x are drawn i.i.d. N(0, I) *inside* the compiled step from
(run_seed, step) so FP32 and MX trajectories see byte-identical batches —
the paper's controlled-comparison protocol (§4.1).

Layers are stacked on a leading axis and folded with ``lax.scan`` so the
lowered HLO stays compact at any depth.

Step functions exported (see aot.py):
  * ``init``   : (seed, init_mode, gain) → state
  * ``step``   : (state…, fmt, hyper, seed, step) → (state…, metrics)
  * ``paired`` : same as step, but additionally computes the FP32 gradient
                 at the same parameter point and reports ‖ε_t‖/‖ḡ_t‖ and
                 cos(g̃_t, ḡ_t) (paper Fig. 4), then applies the *quantized*
                 update.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import formats as F
from . import model as M


@dataclasses.dataclass(frozen=True)
class ProxyConfig:
    depth: int = 4
    d_model: int = 512
    batch: int = 256
    activation: str = "gelu"  # relu | gelu | swiglu
    layernorm: bool = True

    @property
    def hidden(self) -> int:
        if self.activation == "swiglu":
            # 8/3·D keeps parameter parity with 4·D (Shazeer 2020); round to
            # a multiple of 32 so the MX block size divides it.
            h = int(round(self.d_model * 8 / 3 / 32)) * 32
            return max(h, 32)
        return 4 * self.d_model

    @property
    def name(self) -> str:
        ln = "ln" if self.layernorm else "noln"
        return f"proxy_{self.activation}_{ln}_L{self.depth}_D{self.d_model}"

    def n_params(self) -> int:
        per = self.d_model * self.hidden * (3 if self.activation == "swiglu" else 2)
        per += self.d_model if self.layernorm else 0
        return per * self.depth


def _act(cfg: ProxyConfig, h, g=None):
    if cfg.activation == "relu":
        return jax.nn.relu(h)
    if cfg.activation == "gelu":
        return jax.nn.gelu(h)
    if cfg.activation == "swiglu":
        return jax.nn.silu(h) * g
    raise ValueError(cfg.activation)


# --------------------------------------------------------------------------
# Parameters. Student pytree:
#   {"w1": [L, D, H], "w2": [L, H, D], ("wg": [L, D, H])?, ("ln": [L, D])?}
# Teacher uses the same shapes minus "ln".
# --------------------------------------------------------------------------


def init_params(cfg: ProxyConfig, key, init_mode, gain, teacher: bool):
    L, D, H = cfg.depth, cfg.d_model, cfg.hidden
    names = ["w1", "w2"] + (["wg"] if cfg.activation == "swiglu" else [])
    shapes = {"w1": (L, D, H), "w2": (L, H, D), "wg": (L, D, H)}
    fan_in = {"w1": D, "w2": H, "wg": D}
    params = {}
    for i, n in enumerate(names):
        k = jax.random.fold_in(key, i)
        sh = shapes[n]
        # init_mode 0: Kaiming-uniform U(±gain/sqrt(fan_in)) — pytorch default
        # init_mode 1: Xavier-normal with the given gain (Fig. 11 ablation)
        bound = gain / jnp.sqrt(jnp.float32(fan_in[n]))
        ku = jax.random.uniform(k, sh, jnp.float32, -1.0, 1.0) * bound
        xstd = gain * jnp.sqrt(2.0 / jnp.float32(sum(sh[1:])))
        xn = jax.random.normal(k, sh, jnp.float32) * xstd
        params[n] = jnp.where(init_mode > 0.5, xn, ku)
    if cfg.layernorm and not teacher:
        params["ln"] = jnp.ones((L, D), jnp.float32)
    return params


def forward(cfg: ProxyConfig, params, x, fmt):
    """Run the student (or teacher when 'ln' absent). Returns (out, diag)
    where diag = (ln_frac_first, ln_frac_mean, act_frac_mean)."""
    has_ln = "ln" in params
    names = ["w1", "w2"] + (["wg"] if cfg.activation == "swiglu" else [])
    stacked = [params[n] for n in names] + ([params["ln"]] if has_ln else [])

    def block(carry, layer):
        a = carry
        if has_ln:
            *ws, ln_g = layer
        else:
            ws = layer
            ln_g = None
        w1, w2 = ws[0], ws[1]
        if has_ln:
            z, ln_frac = M.layernorm(a, ln_g, fmt)
        else:
            z, ln_frac = a, jnp.float32(0.0)
        h, f1 = M.mx_matmul_stats(z, w1, fmt)
        if cfg.activation == "swiglu":
            g, _ = M.mx_matmul_stats(z, ws[2], fmt)
            phi = _act(cfg, h, g)
        else:
            phi = _act(cfg, h)
        out, f2 = M.mx_matmul_stats(phi, w2, fmt)
        a = a + out
        return a, (ln_frac, (f1 + f2) * 0.5)

    a, (ln_fracs, act_fracs) = jax.lax.scan(block, x, tuple(stacked))
    diag = (
        ln_fracs[0],
        jnp.mean(ln_fracs),
        jnp.mean(act_fracs),
    )
    return a, diag


def loss_fn(cfg: ProxyConfig, params, teacher_params, x, noise, fmt):
    out, diag = forward(cfg, params, x, fmt)
    fp32_fmt = jnp.zeros_like(fmt)  # teacher always runs in full precision
    target, _ = forward(
        dataclasses.replace(cfg, layernorm=False), teacher_params, x, fp32_fmt
    )
    target = jax.lax.stop_gradient(target) + noise
    loss = 0.5 * jnp.mean((out - target) ** 2)
    return loss, diag


# --------------------------------------------------------------------------
# Exported functions (flat signatures; aot.py writes the manifest).
# --------------------------------------------------------------------------


def param_names(cfg: ProxyConfig) -> list[str]:
    names = ["w1", "w2"] + (["wg"] if cfg.activation == "swiglu" else [])
    if cfg.layernorm:
        names.append("ln")
    return names


def teacher_names(cfg: ProxyConfig) -> list[str]:
    return ["w1", "w2"] + (["wg"] if cfg.activation == "swiglu" else [])


def state_spec(cfg: ProxyConfig):
    """Ordered (name, shape) list defining the flat state layout shared with
    the rust coordinator: student params, adam m, adam v, teacher params."""
    L, D, H = cfg.depth, cfg.d_model, cfg.hidden
    shapes = {"w1": (L, D, H), "w2": (L, H, D), "wg": (L, D, H), "ln": (L, D)}
    spec = []
    for prefix in ("p", "m", "v"):
        for n in param_names(cfg):
            spec.append((f"{prefix}_{n}", shapes[n]))
    for n in teacher_names(cfg):
        spec.append((f"t_{n}", shapes[n]))
    return spec


def _unflatten_state(cfg: ProxyConfig, flat):
    names = param_names(cfg)
    tn = teacher_names(cfg)
    k = len(names)
    params = dict(zip(names, flat[:k]))
    ms = dict(zip(names, flat[k : 2 * k]))
    vs = dict(zip(names, flat[2 * k : 3 * k]))
    teacher = dict(zip(tn, flat[3 * k : 3 * k + len(tn)]))
    return params, ms, vs, teacher


def _flatten_state(cfg: ProxyConfig, params, ms, vs, teacher):
    names = param_names(cfg)
    tn = teacher_names(cfg)
    return (
        [params[n] for n in names]
        + [ms[n] for n in names]
        + [vs[n] for n in names]
        + [teacher[n] for n in tn]
    )


def make_init(cfg: ProxyConfig):
    def init(seed, init_mode, gain):
        key = jax.random.PRNGKey(seed)
        params = init_params(cfg, jax.random.fold_in(key, 0), init_mode, gain, False)
        teacher = init_params(cfg, jax.random.fold_in(key, 1), init_mode, gain, True)
        zeros = {n: jnp.zeros_like(p) for n, p in params.items()}
        ms = zeros
        vs = {n: jnp.zeros_like(p) for n, p in params.items()}
        return tuple(_flatten_state(cfg, params, ms, vs, teacher))

    return init


def _batch(cfg: ProxyConfig, seed, step, hyper):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    x = jax.random.normal(jax.random.fold_in(key, 0), (cfg.batch, cfg.d_model))
    eps = jax.random.normal(jax.random.fold_in(key, 1), (cfg.batch, cfg.d_model))
    return x, eps * hyper[F.LABEL_NOISE]


def _metrics(loss, grads, diag, upd_sq, params, extra=None):
    gnorm = M.global_norm(grads)
    met = jnp.zeros((M.MET_LEN,), jnp.float32)
    met = met.at[M.MET_LOSS].set(loss)
    met = met.at[M.MET_GRAD_NORM].set(gnorm)
    met = met.at[M.MET_LN_FRAC_FIRST].set(diag[0])
    met = met.at[M.MET_LN_FRAC_MEAN].set(diag[1])
    met = met.at[M.MET_ACT_FRAC_MEAN].set(diag[2])
    met = met.at[M.MET_UPDATE_NORM].set(jnp.sqrt(upd_sq))
    met = met.at[M.MET_PARAM_NORM].set(M.global_norm(params))
    if extra is not None:
        met = met.at[M.MET_EPS_RATIO].set(extra[0])
        met = met.at[M.MET_COSINE].set(extra[1])
    return met


def make_step(cfg: ProxyConfig, paired: bool = False):
    def step(flat_state, fmt, hyper, seed, step_idx):
        params, ms, vs, teacher = _unflatten_state(cfg, list(flat_state))
        x, noise = _batch(cfg, seed, step_idx, hyper)

        grad_fn = jax.value_and_grad(
            lambda p, f: loss_fn(cfg, p, teacher, x, noise, f), has_aux=True
        )
        (loss, diag), grads = grad_fn(params, fmt)

        extra = None
        if paired:
            fp32 = jnp.zeros_like(fmt)
            (_, _), g_ref = grad_fn(params, fp32)
            diff_sq = sum(
                jnp.sum((a - b) ** 2)
                for a, b in zip(
                    jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(g_ref)
                )
            )
            ref_norm = M.global_norm(g_ref)
            eps_ratio = jnp.sqrt(diff_sq) / (ref_norm + 1e-30)
            cos = M.tree_dot(grads, g_ref) / (
                M.global_norm(grads) * ref_norm + 1e-30
            )
            extra = (eps_ratio, cos)

        params2, ms2, vs2, upd_sq = M.tree_update(params, grads, ms, vs, step_idx, hyper)
        met = _metrics(loss, grads, diag, upd_sq, params2, extra)
        return tuple(_flatten_state(cfg, params2, ms2, vs2, teacher)) + (met,)

    return step
