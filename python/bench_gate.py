#!/usr/bin/env python3
"""Gate the committed bench trajectory against freshly measured numbers.

Two jobs, both stdlib-only (the repo has no python deps):

1. Structural validation of the committed ``BENCH_TRAJECTORY.json``:
   schema, non-empty append-only entries, the last entry naming every
   bench the repo ships, and null headlines only under
   ``measured: false``.

2. Regression gating (``--fresh``): load the freshly regenerated
   ``BENCH_<name>.json`` files at the repo root and

   - require ``measured: true`` and a non-null value for every headline
     key the trajectory's last entry tracks for that bench;
   - when the fresh run is full-size (``smoke_mode: false``), require
     every ``*speedup*`` headline to stay at or above
     ``tolerance x`` the last *measured* trajectory value for the same
     key. Smoke runs (CI) skip the numeric comparison — reduced-size
     numbers are too noisy to gate on — but still enforce presence and
     non-null-ness.

Exit status is nonzero on any violation; every violation is printed.
"""

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
TRAJECTORY = REPO_ROOT / "BENCH_TRAJECTORY.json"

# Every bench binary the repo ships must be tracked by the trajectory's
# newest entry. Extend this set when adding a [[bench]] target.
KNOWN_BENCHES = {"quantizer", "step_throughput", "container_load"}


def fail(errors, msg):
    errors.append(msg)
    print(f"bench_gate: {msg}", file=sys.stderr)


def validate_trajectory(traj, errors):
    if traj.get("schema") != 1:
        fail(errors, f"unknown trajectory schema {traj.get('schema')!r}")
    entries = traj.get("entries")
    if not isinstance(entries, list) or not entries:
        fail(errors, "trajectory has no entries")
        return
    tol = traj.get("tolerance")
    if not isinstance(tol, (int, float)) or not 0 < tol <= 1:
        fail(errors, f"tolerance must be in (0, 1], got {tol!r}")
    seen = set()
    for i, e in enumerate(entries):
        pr = e.get("pr")
        if not isinstance(pr, str) or not pr:
            fail(errors, f"entry {i} has no 'pr' label")
            continue
        if pr in seen:
            fail(errors, f"duplicate entry for {pr!r} (entries are append-only)")
        seen.add(pr)
        heads = e.get("headlines")
        if not isinstance(heads, dict) or not heads:
            fail(errors, f"{pr!r}: no headlines object")
            continue
        unknown = set(heads) - KNOWN_BENCHES
        if unknown:
            fail(errors, f"{pr!r}: unknown benches {sorted(unknown)}")
        for bench, keys in heads.items():
            if not isinstance(keys, dict) or not keys:
                fail(errors, f"{pr!r}/{bench}: empty headline map")
                continue
            for key, val in keys.items():
                if val is None and e.get("measured") is not False:
                    fail(errors, f"{pr!r}/{bench}/{key}: null headline on a measured entry")
                if val is not None and not isinstance(val, (int, float)):
                    fail(errors, f"{pr!r}/{bench}/{key}: non-numeric headline {val!r}")
    last = entries[-1]
    missing = KNOWN_BENCHES - set(last.get("headlines", {}))
    if missing:
        fail(errors, f"last entry {last.get('pr')!r} does not track {sorted(missing)}")


def last_measured(traj, bench, key):
    """Newest trajectory value for headlines[bench][key] on a measured entry."""
    for e in reversed(traj.get("entries", [])):
        if e.get("measured") is not True:
            continue
        val = e.get("headlines", {}).get(bench, {}).get(key)
        if isinstance(val, (int, float)):
            return e["pr"], val
    return None, None


def gate_fresh(traj, errors):
    tol = traj.get("tolerance", 0.8)
    tracked = traj["entries"][-1].get("headlines", {})
    for bench, keys in sorted(tracked.items()):
        path = REPO_ROOT / f"BENCH_{bench}.json"
        if not path.exists():
            fail(errors, f"{path.name}: missing (run `cargo bench --bench {bench}`)")
            continue
        fresh = json.loads(path.read_text())
        if fresh.get("measured") is not True:
            fail(errors, f"{path.name}: measured is not true — placeholder, not a fresh run")
            continue
        smoke = bool(fresh.get("smoke_mode"))
        headline = fresh.get("headline", {})
        for key in sorted(keys):
            val = headline.get(key)
            if not isinstance(val, (int, float)):
                fail(errors, f"{path.name}: headline {key} is {val!r} on a measured run")
                continue
            if smoke or "speedup" not in key:
                continue
            pr, ref = last_measured(traj, bench, key)
            if ref is None:
                continue
            if val < tol * ref:
                fail(
                    errors,
                    f"{path.name}: headline {key} regressed — {val:.3f} vs "
                    f"{ref:.3f} recorded by {pr!r} (tolerance {tol})",
                )
            else:
                print(f"bench_gate: {bench}/{key} ok — {val:.3f} vs {ref:.3f} ({pr!r})")
        if smoke:
            print(f"bench_gate: {bench}: smoke run — presence checked, numbers not gated")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--fresh",
        action="store_true",
        help="also gate freshly measured BENCH_*.json files against the trajectory",
    )
    args = ap.parse_args()
    errors = []
    try:
        traj = json.loads(TRAJECTORY.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_gate: cannot load {TRAJECTORY.name}: {e}", file=sys.stderr)
        return 1
    validate_trajectory(traj, errors)
    if args.fresh and not errors:
        gate_fresh(traj, errors)
    if errors:
        print(f"bench_gate: FAIL ({len(errors)} violation(s))", file=sys.stderr)
        return 1
    print("bench_gate: ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
