"""L2 LM model: architecture invariants, loss semantics, quantization sites."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import formats as F
from compile import lm
from compile import model as M

CFG = lm.LMConfig(n=1, vocab=128, ctx=32, batch=4)


def _fmt(w=F.FP32, a=F.FP32, **kw):
    return jnp.asarray(F.make_fmt(w, a, **kw), jnp.float32)


def _hyper(lr=1e-3):
    h = np.zeros(F.HYPER_LEN, np.float32)
    h[F.LR] = lr
    return jnp.asarray(h)


@pytest.fixture(scope="module")
def state():
    return jax.jit(lm.make_init(CFG))(jnp.int32(0), jnp.float32(0), jnp.float32(1))


@pytest.fixture(scope="module")
def toks():
    return jnp.asarray(
        np.random.RandomState(0).randint(0, CFG.vocab, (CFG.batch, CFG.ctx + 1)),
        jnp.int32,
    )


def test_geometry():
    assert CFG.d_model == 64 and CFG.heads == 1 and CFG.head_dim == 64
    c = lm.LMConfig(n=4)
    assert c.d_model == 256 and c.heads == 4 and c.hidden == 1024


def test_param_count_formula(state):
    spec = lm.state_spec(CFG)
    total = sum(int(np.prod(sh)) for name, sh in spec if name.startswith("p_"))
    assert total == CFG.n_params()


def test_initial_loss_near_uniform(state, toks):
    names = sorted(lm.PARAM_SHAPES(CFG).keys())
    params = dict(zip(names, state[: len(names)]))
    loss, _ = lm.loss_fn(CFG, params, toks, _fmt())
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.6


def test_causality(state):
    """Changing a future token must not affect past logits."""
    names = sorted(lm.PARAM_SHAPES(CFG).keys())
    params = dict(zip(names, state[: len(names)]))
    t = np.random.RandomState(1).randint(0, CFG.vocab, (1, CFG.ctx)).astype(np.int32)
    logits1, _ = lm.forward(CFG, params, jnp.asarray(t), _fmt())
    t2 = t.copy()
    t2[0, -1] = (t2[0, -1] + 1) % CFG.vocab
    logits2, _ = lm.forward(CFG, params, jnp.asarray(t2), _fmt())
    np.testing.assert_allclose(
        np.asarray(logits1)[0, : CFG.ctx - 1],
        np.asarray(logits2)[0, : CFG.ctx - 1],
        rtol=1e-5,
    )
    assert not np.allclose(np.asarray(logits1)[0, -1], np.asarray(logits2)[0, -1])


def test_quantization_perturbs_forward(state, toks):
    names = sorted(lm.PARAM_SHAPES(CFG).keys())
    params = dict(zip(names, state[: len(names)]))
    l_fp, _ = lm.loss_fn(CFG, params, toks, _fmt())
    l_mx, _ = lm.loss_fn(CFG, params, toks, _fmt(F.E2M3, F.E2M3))
    assert float(l_fp) != float(l_mx)
    # fwd-off quantization == fp32 exactly.
    l_off, _ = lm.loss_fn(
        CFG, params, toks, _fmt(F.E2M3, F.E2M3, quant_fwd=False, quant_ln=False)
    )
    assert float(l_fp) == float(l_off)


def test_step_trains(state, toks):
    step = jax.jit(lm.make_step(CFG))
    st = tuple(state)
    losses = []
    for t in range(8):
        out = step(st, toks, _fmt(F.E4M3, F.E4M3), _hyper(3e-3), jnp.int32(0), jnp.int32(t))
        st = out[:-1]
        losses.append(float(out[-1][M.MET_LOSS]))
    assert losses[-1] < losses[0], losses


def test_eval_matches_loss_fn(state, toks):
    ev = jax.jit(lm.make_eval(CFG))
    k = len(lm.state_spec(CFG)) // 3
    (loss,) = ev(tuple(state[:k]), toks, _fmt())
    names = sorted(lm.PARAM_SHAPES(CFG).keys())
    params = dict(zip(names, state[:k]))
    loss2, _ = lm.loss_fn(CFG, params, toks, _fmt())
    # jit vs eager fusion order differs at the last ulp level.
    assert abs(float(loss) - float(loss2)) < 1e-5


def test_paired_metrics(state, toks):
    paired = jax.jit(lm.make_step(CFG, paired=True))
    out = paired(tuple(state), toks, _fmt(F.E5M2, F.E5M2), _hyper(), jnp.int32(0), jnp.int32(0))
    eps = float(out[-1][M.MET_EPS_RATIO])
    cos = float(out[-1][M.MET_COSINE])
    assert 0 < eps < 1 and cos > 0.8


def test_rope_rotation_properties():
    x = jnp.asarray(np.random.RandomState(2).randn(1, 1, 8, 64), jnp.float32)
    y = lm._rope(x)
    # Norm-preserving per position.
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # Position 0 is the identity rotation.
    np.testing.assert_allclose(np.asarray(y)[..., 0, :], np.asarray(x)[..., 0, :], rtol=1e-6)
