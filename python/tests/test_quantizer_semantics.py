"""Semantic properties of the MX quantizer (paper Algorithm 1 + §6.1)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F
from compile.kernels import ref

MX_FORMATS = [F.E4M3, F.E5M2, F.E2M3, F.E3M2]
MAX_NORM = {k: v[3] for k, v in F.MX_CONSTANTS.items()}
MBITS = {k: v[1] for k, v in F.MX_CONSTANTS.items()}


def qdq(x, fid, bump=0.0):
    y, lb = ref.qdq(jnp.asarray(x, jnp.float32), jnp.float32(fid), jnp.float32(bump))
    return np.asarray(y), np.asarray(lb)


def test_exact_values_pass_through_e4m3():
    vals = np.array([1.0, -1.125, 448.0, 0.0625, 2.0, 3.5] + [0.0] * 26, np.float32)
    y, _ = qdq(vals.reshape(1, 32), F.E4M3)
    # With blockmax 448 the scale is 1.0 → values on the grid are preserved.
    np.testing.assert_array_equal(y.ravel(), vals)


def test_paper_lognormal_block_clamps_everything():
    block = np.full((1, 32), 0.89, np.float32)
    block[0, :5] = [0.89740956, 0.89628334, 0.88358812, 0.88474816, 0.90372837]
    y, lb = qdq(block, F.E4M3)
    assert lb.all(), "every element should land in the last bin"
    assert np.unique(y).size == 1, "heterogeneity is lost (all clamp to 448·2^-9)"
    np.testing.assert_allclose(y, 448.0 * 2.0**-9)


def test_eq10_overflow_criterion():
    # Block max mantissa 1.9 → scale 2^-8. The last bin starts where RNE
    # rounds to 448, i.e. scaled values ≥ 432 (= 448 − step/2, step 32).
    block = np.full((1, 32), 0.1, np.float32)
    block[0, 0] = 1.9          # scaled 486 → clamps
    block[0, 1] = 0.93 * 1.9   # scaled 452 → clamps (rounds to 448)
    block[0, 2] = 0.85 * 1.9   # scaled 413 → rounds to 416, below last bin
    _, lb = qdq(block, F.E4M3)
    assert lb[0, 0] and lb[0, 1]
    assert not lb[0, 2]


def test_scale_bump_clears_last_bin():
    # Cluster around 0.9 (mantissa-of-max ≈ 1.8): the §6.1 clamping regime.
    x = (0.9 * np.exp(np.random.RandomState(0).randn(4, 128) * 0.01)).astype(np.float32)
    _, lb0 = qdq(x, F.E4M3, bump=0.0)
    _, lb1 = qdq(x, F.E4M3, bump=1.0)
    assert lb0.mean() > 0.1
    assert lb1.mean() == 0.0


def test_zero_blocks_stay_zero():
    x = np.zeros((2, 64), np.float32)
    x[1, 40] = 1e-30
    y, _ = qdq(x, F.E4M3)
    assert (y[0] == 0).all()


def test_bf16_path_matches_numpy_cast():
    x = np.random.RandomState(1).randn(8, 64).astype(np.float32)
    y, _ = qdq(x, F.BF16)
    import ml_dtypes

    expect = x.astype(ml_dtypes.bfloat16).astype(np.float32)
    np.testing.assert_array_equal(y, expect)


def test_fp32_is_identity():
    x = np.random.RandomState(2).randn(8, 64).astype(np.float32) * 1e20
    y, lb = qdq(x, F.FP32)
    np.testing.assert_array_equal(y, x)
    assert lb.sum() == 0


@settings(max_examples=40, deadline=None)
@given(
    fid=st.sampled_from(MX_FORMATS),
    seed=st.integers(0, 2**31 - 1),
    log_scale=st.integers(-20, 20),
)
def test_idempotence(fid, seed, log_scale):
    x = (np.random.RandomState(seed).randn(2, 64) * 2.0**log_scale).astype(np.float32)
    y, _ = qdq(x, fid)
    y2, _ = qdq(y, fid)
    np.testing.assert_array_equal(y, y2)


@settings(max_examples=40, deadline=None)
@given(fid=st.sampled_from(MX_FORMATS), seed=st.integers(0, 2**31 - 1))
def test_relative_error_bound(fid, seed):
    """Non-clamped normal-band values have rel err ≤ 2^-(mbits+1)."""
    x = np.random.RandomState(seed).randn(2, 64).astype(np.float32)
    y, lb = qdq(x, fid)
    xb = x.reshape(-1, 32)
    yb = y.reshape(-1, 32)
    lbb = lb.reshape(-1, 32)
    emax = F.MX_CONSTANTS[fid][2]
    emin = F.MX_CONSTANTS[fid][4]
    for b in range(xb.shape[0]):
        m = np.abs(xb[b]).max()
        if m == 0:
            continue
        scale = 2.0 ** (np.floor(np.log2(m)) - emax)
        for v, q, clamped in zip(xb[b], yb[b], lbb[b]):
            if clamped or v == 0 or abs(v / scale) < 2.0**emin:
                continue
            rel = abs((q - v) / v)
            assert rel <= 2.0 ** -(MBITS[fid] + 1) * (1 + 1e-5), (v, q, rel)


@settings(max_examples=40, deadline=None)
@given(fid=st.sampled_from(MX_FORMATS), seed=st.integers(0, 2**31 - 1))
def test_odd_symmetry(fid, seed):
    x = np.random.RandomState(seed).randn(2, 64).astype(np.float32)
    y, _ = qdq(x, fid)
    yn, _ = qdq(-x, fid)
    np.testing.assert_array_equal(y, -yn)


def test_qdq_axis_argument():
    x = np.random.RandomState(3).randn(64, 32).astype(np.float32)
    y0, _ = ref.qdq(jnp.asarray(x), jnp.float32(F.E4M3), jnp.float32(0), axis=0)
    yt, _ = ref.qdq(jnp.asarray(x.T), jnp.float32(F.E4M3), jnp.float32(0), axis=-1)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(yt).T)


def test_ste_gradient_is_identity():
    import jax

    x = jnp.asarray(np.random.RandomState(4).randn(1, 32), jnp.float32)

    def f(v):
        y, _ = ref.qdq_ste(v, jnp.float32(F.E4M3), jnp.float32(0))
        return jnp.sum(y * y)

    g = jax.grad(f)(x)
    # STE: dy/dx = 1 while y = q(x) → df/dx = 2·q(x).
    q, _ = qdq(np.asarray(x), F.E4M3)
    np.testing.assert_allclose(np.asarray(g), 2 * q, rtol=1e-6)
