"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

The Pallas kernel must agree *bit-for-bit* with ref.py across shapes,
dtypes-of-input distribution and every element format — hypothesis sweeps
the space. This is the core correctness signal for the whole stack: the
rust mirror and the compiled HLO artifacts are tested against the same
oracle from the rust side.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats as F
from compile.kernels import mx, ref

ALL_FORMATS = [F.FP32, F.BF16, F.E4M3, F.E5M2, F.E2M3, F.E3M2]
MX_FORMATS = [F.E4M3, F.E5M2, F.E2M3, F.E3M2]


def _rand(shape, seed=0, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)


@pytest.mark.parametrize("fid", ALL_FORMATS)
@pytest.mark.parametrize(
    "shape", [(8, 256), (16, 512), (128, 512), (8, 32), (24, 1024)]
)
def test_pallas_matches_ref_bitexact(fid, shape):
    x = _rand(shape, seed=fid)
    y_ref, lb_ref = ref.qdq(jnp.asarray(x), jnp.float32(fid), jnp.float32(0))
    y_pal, lb_pal = mx.mx_qdq_pallas(jnp.asarray(x), float(fid), 0.0)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_pal))
    np.testing.assert_array_equal(
        np.asarray(lb_ref, np.float32), np.asarray(lb_pal)
    )


@pytest.mark.parametrize("fid", MX_FORMATS)
def test_pallas_scale_bump(fid):
    x = np.exp(_rand((8, 256), seed=1, scale=0.01))  # tight cluster
    y_ref, _ = ref.qdq(jnp.asarray(x), jnp.float32(fid), jnp.float32(1))
    y_pal, _ = mx.mx_qdq_pallas(jnp.asarray(x), float(fid), 1.0)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_pal))


@settings(max_examples=30, deadline=None)
@given(
    fid=st.sampled_from(MX_FORMATS),
    rows=st.integers(1, 9),
    cols_blocks=st.sampled_from([1, 2, 4, 8, 16]),
    log_scale=st.integers(-30, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_pallas_vs_ref(fid, rows, cols_blocks, log_scale, seed):
    shape = (rows, 32 * cols_blocks)
    x = _rand(shape, seed=seed, scale=2.0**log_scale)
    y_ref, lb_ref = ref.qdq(jnp.asarray(x), jnp.float32(fid), jnp.float32(0))
    y_pal, lb_pal = mx.mx_qdq_pallas(jnp.asarray(x), float(fid), 0.0)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_pal))
    np.testing.assert_array_equal(np.asarray(lb_ref, np.float32), np.asarray(lb_pal))


@settings(max_examples=25, deadline=None)
@given(
    fid=st.sampled_from(MX_FORMATS),
    style=st.sampled_from(["normal", "cluster", "sparse", "huge", "tiny"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_distribution_styles(fid, style, seed):
    rs = np.random.RandomState(seed)
    if style == "normal":
        x = rs.randn(4, 128)
    elif style == "cluster":
        x = np.exp(rs.randn(4, 128) * 0.01)
    elif style == "sparse":
        x = rs.randn(4, 128) * (rs.rand(4, 128) > 0.8)
    elif style == "huge":
        x = rs.randn(4, 128) * 1e30
    else:
        x = rs.randn(4, 128) * 1e-30
    x = x.astype(np.float32)
    y_ref, _ = ref.qdq(jnp.asarray(x), jnp.float32(fid), jnp.float32(0))
    y_pal, _ = mx.mx_qdq_pallas(jnp.asarray(x), float(fid), 0.0)
    np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_pal))
