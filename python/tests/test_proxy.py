"""L2 proxy model: shapes, determinism, optimizer semantics, diagnostics."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import formats as F
from compile import model as M
from compile import proxy


CFG = proxy.ProxyConfig(depth=2, d_model=64, batch=64)


def _fmt(w=F.FP32, a=F.FP32, **kw):
    return jnp.asarray(F.make_fmt(w, a, **kw), jnp.float32)


def _hyper(lr=1e-3, opt_mode=0.0, momentum=0.0, noise=1e-3):
    h = np.zeros(F.HYPER_LEN, np.float32)
    h[F.LR] = lr
    h[F.OPT_MODE] = opt_mode
    h[F.MOMENTUM] = momentum
    h[F.LABEL_NOISE] = noise
    return jnp.asarray(h)


@pytest.fixture(scope="module")
def state():
    init = jax.jit(proxy.make_init(CFG))
    return init(jnp.int32(0), jnp.float32(0), jnp.float32(1.0))


@pytest.fixture(scope="module")
def step():
    return jax.jit(proxy.make_step(CFG))


def test_state_spec_matches_init(state):
    spec = proxy.state_spec(CFG)
    assert len(spec) == len(state)
    for (name, shape), arr in zip(spec, state):
        assert tuple(shape) == arr.shape, name


def test_hidden_sizes():
    assert proxy.ProxyConfig(activation="gelu", d_model=512).hidden == 2048
    sw = proxy.ProxyConfig(activation="swiglu", d_model=512)
    assert sw.hidden % 32 == 0
    assert abs(sw.hidden - 512 * 8 / 3) < 32


def test_param_count():
    cfg = proxy.ProxyConfig(depth=3, d_model=128)
    n = cfg.n_params()
    assert n == 3 * (2 * 128 * 512 + 128)


def test_fp32_fmt_is_noop_vs_manual_forward(state):
    params, _, _, teacher = proxy._unflatten_state(CFG, list(state))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, CFG.d_model))
    out_fp, _ = proxy.forward(CFG, params, x, _fmt())
    out_q, _ = proxy.forward(
        CFG, params, x, _fmt(F.E4M3, F.E4M3)
    )
    assert not np.allclose(np.asarray(out_fp), np.asarray(out_q)), (
        "quantization must perturb the forward pass"
    )
    # fmt with quant flags off equals fmt id fp32.
    out_off, _ = proxy.forward(
        CFG, params, x, _fmt(F.E4M3, F.E4M3, quant_fwd=False, quant_ln=False)
    )
    np.testing.assert_array_equal(np.asarray(out_fp), np.asarray(out_off))


def test_step_determinism(state, step):
    a = step(tuple(state), _fmt(), _hyper(), jnp.int32(3), jnp.int32(7))
    b = step(tuple(state), _fmt(), _hyper(), jnp.int32(3), jnp.int32(7))
    np.testing.assert_array_equal(np.asarray(a[-1]), np.asarray(b[-1]))
    c = step(tuple(state), _fmt(), _hyper(), jnp.int32(3), jnp.int32(8))
    assert not np.array_equal(np.asarray(a[-1]), np.asarray(c[-1])), (
        "different step index must draw different data"
    )


def test_loss_decreases_fp32(state, step):
    st = tuple(state)
    losses = []
    for t in range(25):
        out = step(st, _fmt(), _hyper(lr=1e-3), jnp.int32(0), jnp.int32(t))
        st = out[:-1]
        losses.append(float(out[-1][M.MET_LOSS]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]


def test_sgd_vs_adam_updates_differ(state, step):
    a = step(tuple(state), _fmt(), _hyper(opt_mode=0.0), jnp.int32(0), jnp.int32(0))
    s = step(tuple(state), _fmt(), _hyper(opt_mode=1.0, momentum=0.9), jnp.int32(0), jnp.int32(0))
    # Same gradient, different optimizer → same loss, different update norm.
    assert float(a[-1][M.MET_LOSS]) == float(s[-1][M.MET_LOSS])
    assert float(a[-1][M.MET_UPDATE_NORM]) != float(s[-1][M.MET_UPDATE_NORM])


def test_sgd_momentum_accumulates(state):
    step = jax.jit(proxy.make_step(CFG))
    st = tuple(state)
    h = _hyper(lr=1e-3, opt_mode=1.0, momentum=0.9)
    norms = []
    for t in range(4):
        out = step(st, _fmt(), h, jnp.int32(0), jnp.int32(t))
        st = out[:-1]
        norms.append(float(out[-1][M.MET_UPDATE_NORM]))
    assert norms[2] > norms[0], "momentum should build up the update norm"


def test_ln_diag_zero_at_init_and_nonzero_for_cluster(state, step):
    # At init gammas are all ones → mantissa 1.0 → no clamping (§6.1).
    out = step(tuple(state), _fmt(F.E4M3, F.E4M3), _hyper(), jnp.int32(0), jnp.int32(0))
    assert float(out[-1][M.MET_LN_FRAC_FIRST]) == 0.0
    # Force a clustered gamma with mantissa ≈1.8 → clamping appears.
    st = list(state)
    spec = proxy.state_spec(CFG)
    ln_idx = [i for i, (n, _) in enumerate(spec) if n == "p_ln"][0]
    st[ln_idx] = jnp.full(st[ln_idx].shape, 1.8) + jax.random.uniform(
        jax.random.PRNGKey(0), st[ln_idx].shape, jnp.float32, -0.01, 0.01
    )
    out = step(tuple(st), _fmt(F.E4M3, F.E4M3), _hyper(), jnp.int32(0), jnp.int32(0))
    assert float(out[-1][M.MET_LN_FRAC_FIRST]) > 0.9
    # ...and quant_ln=False suppresses the diagnostic (and the quantization).
    out = step(
        tuple(st),
        _fmt(F.E4M3, F.E4M3, quant_ln=False),
        _hyper(),
        jnp.int32(0),
        jnp.int32(0),
    )
    assert float(out[-1][M.MET_LN_FRAC_FIRST]) == 0.0


def test_paired_step_consistency(state):
    paired = jax.jit(proxy.make_step(CFG, paired=True))
    out = paired(tuple(state), _fmt(F.E4M3, F.E4M3), _hyper(), jnp.int32(0), jnp.int32(0))
    eps, cos = float(out[-1][M.MET_EPS_RATIO]), float(out[-1][M.MET_COSINE])
    assert 0 < eps < 1 and 0.9 < cos <= 1.0
    out = paired(tuple(state), _fmt(), _hyper(), jnp.int32(0), jnp.int32(0))
    assert float(out[-1][M.MET_EPS_RATIO]) == 0.0
    assert abs(float(out[-1][M.MET_COSINE]) - 1.0) < 1e-5


@pytest.mark.parametrize("act", ["relu", "gelu", "swiglu"])
@pytest.mark.parametrize("ln", [True, False])
def test_all_architectures_step(act, ln):
    cfg = proxy.ProxyConfig(depth=2, d_model=64, batch=32, activation=act, layernorm=ln)
    st = jax.jit(proxy.make_init(cfg))(jnp.int32(0), jnp.float32(0), jnp.float32(1))
    step = jax.jit(proxy.make_step(cfg))
    out = step(tuple(st), _fmt(F.E5M2, F.E5M2), _hyper(), jnp.int32(0), jnp.int32(0))
    loss = float(out[-1][M.MET_LOSS])
    assert np.isfinite(loss) and loss > 0


def test_init_modes_differ():
    init = jax.jit(proxy.make_init(CFG))
    k = init(jnp.int32(0), jnp.float32(0), jnp.float32(1.0))
    x = init(jnp.int32(0), jnp.float32(1), jnp.float32(0.5))
    a, b = np.asarray(k[0]), np.asarray(x[0])
    assert not np.array_equal(a, b)
    # Kaiming-uniform is bounded; Xavier-normal with low gain has smaller std.
    assert np.abs(a).max() <= 1 / np.sqrt(CFG.d_model) + 1e-6
    assert b.std() < a.std()


def test_teacher_is_not_updated(state, step):
    out = step(tuple(state), _fmt(), _hyper(), jnp.int32(0), jnp.int32(0))
    spec = proxy.state_spec(CFG)
    for i, (name, _) in enumerate(spec):
        if name.startswith("t_"):
            np.testing.assert_array_equal(np.asarray(state[i]), np.asarray(out[i]), name)
