"""AOT pipeline: HLO text emission, manifest consistency, bundle registry."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, bundles, formats as F
from compile.proxy import ProxyConfig
from compile.lm import LMConfig

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_bundle_sets_are_wellformed():
    for set_name in ("quick", "default", "full"):
        bs = bundles.bundle_set(set_name)
        names = [b.name for b in bs]
        assert len(names) == len(set(names)), "duplicate bundle names"
        assert "quantizer" in names
        assert any(n.startswith("proxy_") for n in names)
        assert any(n.startswith("lm_") for n in names)
    with pytest.raises(ValueError):
        bundles.bundle_set("nope")


def test_hlo_text_emission_smoke(tmp_path):
    """Lower a tiny function and verify parseable HLO text is emitted."""

    def fn(x):
        return (x * 2.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    # HLO *text*, not a serialized proto (the xla 0.1.6 interchange rule).
    assert text.startswith("HloModule")


def test_quantizer_bundle_compiles(tmp_path):
    aot.compile_quantizer(str(tmp_path))
    man = json.load(open(tmp_path / "manifest.json"))
    assert man["kind"] == "quantizer"
    assert man["block_size"] == 32
    step = man["functions"]["step"]
    assert [i["name"] for i in step["inputs"]] == ["x", "fmt_id", "scale_bump"]
    hlo = open(tmp_path / "step.hlo.txt").read()
    assert "HloModule" in hlo


def test_proxy_bundle_manifest_consistency(tmp_path):
    b = bundles.Bundle(ProxyConfig(depth=2, d_model=64, batch=32), paired=True)
    aot.compile_proxy(b, str(tmp_path))
    man = json.load(open(tmp_path / "manifest.json"))
    state = man["state"]
    step = man["functions"]["step"]
    # step inputs = state ++ [fmt, hyper, seed, step]
    assert [i["name"] for i in step["inputs"][: len(state)]] == [s["name"] for s in state]
    tail = [i["name"] for i in step["inputs"][len(state) :]]
    assert tail == ["fmt", "hyper", "seed", "step"]
    # step outputs = state ++ [metrics]
    assert [o["name"] for o in step["outputs"][:-1]] == [s["name"] for s in state]
    assert step["outputs"][-1]["name"] == "metrics"
    assert step["outputs"][-1]["shape"] == [9]
    assert "paired" in man["functions"]
    # init outputs match state.
    init = man["functions"]["init"]
    assert [o["name"] for o in init["outputs"]] == [s["name"] for s in state]
    assert man["n_params"] == ProxyConfig(depth=2, d_model=64, batch=32).n_params()


def test_lm_bundle_manifest_consistency(tmp_path):
    b = bundles.Bundle(LMConfig(n=1, vocab=64, ctx=32, batch=2))
    aot.compile_lm(b, str(tmp_path))
    man = json.load(open(tmp_path / "manifest.json"))
    state = man["state"]
    step = man["functions"]["step"]
    tail = [i["name"] for i in step["inputs"][len(state) :]]
    assert tail == ["tokens", "fmt", "hyper", "seed", "step"]
    ev = man["functions"]["eval"]
    k = len(state) // 3
    assert [i["name"] for i in ev["inputs"][:k]] == [s["name"] for s in state[:k]]
    assert man["flops_per_step"] > 0
    assert man["metrics"][0] == "loss"


def test_fmt_metadata_matches_formats_module(tmp_path):
    aot.compile_quantizer(str(tmp_path))
    man = json.load(open(tmp_path / "manifest.json"))
    assert man["formats"] == {
        "fp32": F.FP32,
        "bf16": F.BF16,
        "e4m3": F.E4M3,
        "e5m2": F.E5M2,
        "e2m3": F.E2M3,
        "e3m2": F.E3M2,
    }


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts not built")
def test_built_artifacts_have_index():
    idx = os.path.join(ART, "index.json")
    if not os.path.exists(idx):
        pytest.skip("no index.json")
    index = json.load(open(idx))
    for name in index["bundles"]:
        man_path = os.path.join(ART, name, "manifest.json")
        assert os.path.exists(man_path), name
        man = json.load(open(man_path))
        for fn in man["functions"].values():
            assert os.path.exists(os.path.join(ART, name, fn["file"]))
